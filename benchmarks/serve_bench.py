"""End-to-end serving throughput: continuous batching (paged packed-KV
engine, ``repro.serve.scheduler``) vs the static-batch ``greedy_generate``
baseline, at mixed prompt/output lengths.

The workload is deliberately skewed (each group of ``slots`` requests has
one long output and several short ones): a static batch decodes every
group for its longest member, so most lanes idle; the continuous engine
evicts finished lanes and backfills from the queue. Rows report
tokens/sec, mean batch occupancy and page-pool utilization; ``--json``
persists them to ``BENCH_serving.json`` (the serving-side trajectory CI
uploads beside ``BENCH_kernels.json``). ``--smoke`` shrinks the model and
workload to a CI-sized CPU pass on the jnp route.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import reduced_config
from repro.core.policy import QuantPolicy
from repro.serve import engine as E
from repro.serve.scheduler import (ContinuousBatchingEngine, Request,
                                   SamplingParams)

BENCH_SCHEMA = "repro/serve_bench/v1"
DEFAULT_JSON = os.path.normpath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)),
                 os.pardir, "BENCH_serving.json"))

FP = QuantPolicy(base_w_nf4=False, a_bits=None, w_bits=None, g_bits=None,
                 adapter_bits=None, fmt="none", rank=8)


def write_json(records, path: str, smoke: bool):
    doc = {"schema": BENCH_SCHEMA, "smoke": bool(smoke),
           "backend": jax.default_backend(), "rows": records}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path


def _workload(groups: int, slots: int, long_new: int, short_new: int,
              long_prompt: int, short_prompt: int, vocab: int):
    """``groups`` batches of ``slots`` requests, one long per group."""
    rng = np.random.default_rng(0)
    reqs = []
    for g in range(groups):
        for s in range(slots):
            long = s == 0
            t = long_prompt if long else short_prompt
            reqs.append(Request(
                uid=g * slots + s,
                prompt=rng.integers(4, vocab, size=(t,)).astype(np.int32),
                max_new=long_new if long else short_new))
    return reqs


def _static_run(fz, tr, reqs, slots, gen, kv_bits):
    """Static batching: groups of ``slots`` in arrival order, prompts
    right-padded to the group max, every lane decoded for the group's
    longest request (the idle-lane cost the engine removes). ``gen`` is
    the shared jit cache (one trace per (max_new, kv_bits))."""
    outs = {}
    steps = 0
    for i in range(0, len(reqs), slots):
        group = reqs[i:i + slots]
        tmax = max(len(r.prompt) for r in group)
        mn = max(r.max_new for r in group)
        prompts = np.ones((len(group), tmax), np.int32)
        for j, r in enumerate(group):
            prompts[j, :len(r.prompt)] = r.prompt
        toks = gen(mn, kv_bits)(fz, tr, jnp.asarray(prompts))
        jax.block_until_ready(toks)
        steps += mn
        for j, r in enumerate(group):
            outs[r.uid] = np.asarray(toks[j, :r.max_new])
    return outs, steps


def run(smoke: bool = False, records=None):
    rows = []
    if records is None:
        records = []
    # serving-sized (not the test-sized reduced config): per-step compute
    # must dominate dispatch overhead or the comparison measures the
    # Python loop, not the batching policy
    import dataclasses
    cfg = dataclasses.replace(
        reduced_config("granite_3_2b"), n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=768, vocab=512)
    from repro.models import model as M
    fz, tr = M.init_model(jax.random.PRNGKey(0), cfg, FP)

    # one long request per group of `slots`: static batching decodes every
    # group for its longest member while the engine runs the longs from
    # different groups *concurrently* (groups == slots keeps exactly one
    # long per lane) and turns the short lanes over
    slots = 4
    page = 8
    if smoke:
        groups, long_new, short_new = 4, 96, 2
        long_prompt, short_prompt = 12, 4
    else:
        groups, long_new, short_new = 4, 128, 4
        long_prompt, short_prompt = 16, 8
    max_pages = -(-(long_prompt + long_new) // page)
    s_cap = page * max_pages
    reqs = _workload(groups, slots, long_new, short_new,
                     long_prompt, short_prompt, cfg.vocab)
    total_tokens = sum(r.max_new for r in reqs)

    def make_engine(kv_bits):
        return ContinuousBatchingEngine(
            fz, tr, cfg, FP, slots=slots, page_size=page,
            max_pages_per_slot=max_pages, kv_quant_bits=kv_bits)

    from functools import lru_cache

    @lru_cache(maxsize=None)
    def gen(max_new, kv_bits):
        return jax.jit(lambda fz, tr, p: E.greedy_generate(
            fz, tr, p, cfg, FP, max_new=max_new, max_len=s_cap,
            kv_quant_bits=kv_bits))

    for kv_bits in (None, 8):
        tag = "fp" if kv_bits is None else f"kv{kv_bits}"
        # warm both paths (jit caches are process-wide), then time fresh
        # runs — compile time is not a throughput claim
        warm = make_engine(kv_bits)
        for r in reqs[:slots + 1]:
            warm.submit(r)
        warm.run()
        _static_run(fz, tr, reqs[:slots], slots, gen, kv_bits)

        eng = make_engine(kv_bits)
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        cont = eng.run()
        t_cont = time.perf_counter() - t0
        summ = eng.summary()

        t0 = time.perf_counter()
        stat, static_steps = _static_run(fz, tr, reqs, slots, gen, kv_bits)
        t_stat = time.perf_counter() - t0

        assert set(cont) == set(stat) and len(cont) == len(reqs)
        tps_c = total_tokens / t_cont
        tps_s = total_tokens / t_stat
        util = summ.get("page_utilization")
        rows.append(csv_row(
            f"serve/continuous_{tag}", t_cont * 1e6,
            f"tok/s={tps_c:.1f} occupancy={summ['occupancy']:.2f} "
            f"speedup={tps_c / tps_s:.2f}x steps={summ['steps']}"))
        rows.append(csv_row(
            f"serve/static_{tag}", t_stat * 1e6,
            f"tok/s={tps_s:.1f} steps={static_steps}"))
        base = {"requests": len(reqs), "tokens": total_tokens,
                "kv_bits": kv_bits, "slots": slots,
                "workload": f"g{groups}long{long_new}short{short_new}"}
        records.append(dict(base, mode="continuous",
                            wall_s=round(t_cont, 3),
                            tokens_per_sec=round(tps_c, 2),
                            decode_steps=summ["steps"],
                            occupancy=round(summ["occupancy"], 4),
                            page_utilization=(round(util, 4)
                                              if util is not None else None),
                            speedup_vs_static=round(tps_c / tps_s, 3)))
        records.append(dict(base, mode="static", wall_s=round(t_stat, 3),
                            tokens_per_sec=round(tps_s, 2),
                            decode_steps=static_steps,
                            occupancy=None, page_utilization=None,
                            speedup_vs_static=1.0))

    # mixed per-request read widths over the one 8-bit pool: each lane
    # attends through its own plane-prefix of the shared stored planes
    # (SamplingParams.kv_bits), one fused decode block for all lanes — no
    # per-width engine, no retrace at admission. Same workload as the kv8
    # row; the width cycle covers narrow/mid/full/default lanes.
    widths = (4, 6, 8, None)
    mixed = [Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new,
                     sampling=SamplingParams(kv_bits=widths[i % len(widths)]))
             for i, r in enumerate(reqs)]
    warm = make_engine(8)
    for r in mixed[:slots + 1]:
        warm.submit(r)
    warm.run()
    eng = make_engine(8)
    for r in mixed:
        eng.submit(r)
    t0 = time.perf_counter()
    cont = eng.run()
    t_mixed = time.perf_counter() - t0
    summ = eng.summary()
    assert len(cont) == len(reqs)
    tps_m = total_tokens / t_mixed
    util = summ.get("page_utilization")
    rows.append(csv_row(
        "serve/continuous_mixed_kv"
        + "-".join("full" if w is None else str(w) for w in widths),
        t_mixed * 1e6,
        f"tok/s={tps_m:.1f} occupancy={summ['occupancy']:.2f} "
        f"widths={widths} steps={summ['steps']}"))
    records.append({"requests": len(reqs), "tokens": total_tokens,
                    "kv_bits": "mixed:4/6/8/none", "slots": slots,
                    "workload": f"g{groups}long{long_new}short{short_new}",
                    "mode": "continuous", "wall_s": round(t_mixed, 3),
                    "tokens_per_sec": round(tps_m, 2),
                    "decode_steps": summ["steps"],
                    "occupancy": round(summ["occupancy"], 4),
                    "page_utilization": (round(util, 4)
                                         if util is not None else None),
                    "speedup_vs_static": None})
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized pass (tiny model/workload, CPU jnp "
                         "route); also writes the JSON trajectory file")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON, default=None,
                    metavar="PATH",
                    help=f"write structured rows (default {DEFAULT_JSON})")
    args = ap.parse_args()
    recs = []
    print("\n".join(run(smoke=args.smoke, records=recs)))
    json_path = args.json or (DEFAULT_JSON if args.smoke else None)
    if json_path:
        print(f"wrote {write_json(recs, json_path, args.smoke)}")
