"""Benchmark entry point — one function per paper table. Prints
``name,us_per_call,derived`` CSV. ``python -m benchmarks.run [--quick]``."""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer proxy-finetune steps")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,table5,table6,table7,"
                         "pareto,memory,kernels")
    args = ap.parse_args()
    steps = 40 if args.quick else 120
    sel = set(args.only.split(",")) if args.only else None

    from benchmarks import tables, kernel_bench, memory_model

    jobs = [
        ("table5", lambda: tables.table5_hardware()),
        ("memory", lambda: memory_model.run(print_csv=False)),
        ("kernels", lambda: kernel_bench.run()),
        ("table2", lambda: tables.table2_fp8(steps)),
        ("table1", lambda: tables.table1_bits(steps)),
        ("table6", lambda: tables.table6_group(steps)),
        ("table7", lambda: tables.table7_rank(steps)),
        ("pareto", lambda: tables.pareto(max(steps * 2 // 3, 30))),
    ]
    print("name,us_per_call,derived")
    for name, fn in jobs:
        if sel and name not in sel:
            continue
        t0 = time.time()
        try:
            for row in fn():
                print(row, flush=True)
        except Exception as e:  # keep the harness running
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}",
                  file=sys.stderr)
            raise
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
