"""Shared proxy-experiment machinery for the paper-table benchmarks.

The paper fine-tunes LLaMA on Alpaca; at CPU scale we fine-tune a small
GSQ-LoRA transformer on the synthetic instruction tasks (learnable:
copy/reverse/sort) and compare *policies* — the quantity the paper varies.
Each benchmark prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import QuantPolicy
from repro.data.pipeline import DataConfig, batch_at_step
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.adamw8bit import AdamW8bit
from repro.train.step import TrainConfig, make_train_step, lm_loss

PROXY_CFG = ModelConfig(
    name="proxy", family="dense", n_layers=2, d_model=96, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=128, vocab_pad_multiple=64)

PROXY_DATA = DataConfig(vocab=128, seq_len=64, global_batch=16,
                        task_mix=("copy", "reverse", "sort"), seed=99)


def run_proxy_finetune(policy: QuantPolicy, steps: int = 120,
                       lr: float = 5e-3, seed: int = 0,
                       cfg: ModelConfig = PROXY_CFG,
                       data: DataConfig = PROXY_DATA,
                       record_every: int = 0):
    """Fine-tune the proxy model under ``policy``; returns metrics dict with
    eval loss/accuracy and wall time per step. ``record_every > 0`` also
    collects ``loss_trajectory`` — a list of (step, train_loss) pairs — the
    curve the residual-width sweep tabulates."""
    fz, tr = M.init_model(jax.random.PRNGKey(seed), cfg, policy)
    # cosine decay for every policy alike: at proxy scale a constant 5e-3
    # LR makes *any* weight-quantized run oscillate late in training (the
    # classic QAT oscillation regime — the paper itself fine-tunes at a
    # constant 1e-5, 500x lower); decay restores the paper's stable regime
    # within the proxy budget.
    opt = AdamW8bit(lr=lr, warmup_steps=10, schedule="cosine",
                    total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, policy, opt, TrainConfig()))
    opt_state = opt.init(tr)
    res = jax.tree.map(lambda p: jnp.zeros((0,), jnp.float32), tr)
    t0 = time.perf_counter()
    loss = None
    best = float("inf")
    trajectory = []
    for s in range(steps):
        batch = jax.tree.map(jnp.asarray, batch_at_step(data, s))
        tr, opt_state, res, metrics = step_fn(fz, tr, opt_state, res, batch)
        loss = metrics["loss"]
        if s % 10 == 9:
            best = min(best, float(loss))
        if record_every and (s % record_every == record_every - 1
                             or s == steps - 1):
            trajectory.append((s + 1, float(loss)))
    jax.block_until_ready(loss)
    dt = (time.perf_counter() - t0) / steps
    ev = evaluate(fz, tr, cfg, policy, data)
    ev["train_loss"] = float(loss)
    ev["best_train_loss"] = min(best, float(loss))
    ev["us_per_step"] = dt * 1e6
    if record_every:
        ev["loss_trajectory"] = trajectory
    return ev


def evaluate(fz, tr, cfg, policy, data: DataConfig, batches=4,
             start_step=10_000):
    """Held-out eval: masked CE + response-token accuracy."""
    tot_loss, tot_tok, tot_correct = 0.0, 0.0, 0.0
    for i in range(batches):
        b = jax.tree.map(jnp.asarray, batch_at_step(data, start_step + i))
        logits = M.forward(fz, tr, b, cfg, policy).astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, -1)
        ll = jnp.take_along_axis(lp, b["labels"][..., None], -1)[..., 0]
        mask = b["loss_mask"]
        tot_loss += float(jnp.sum(-ll * mask))
        tot_tok += float(jnp.sum(mask))
        pred = jnp.argmax(logits, -1)
        tot_correct += float(jnp.sum((pred == b["labels"]) * mask))
    return {"eval_loss": tot_loss / tot_tok,
            "eval_acc": tot_correct / tot_tok}


def csv_row(name: str, us_per_call: float, derived) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
