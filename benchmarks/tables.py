"""Paper-table benchmarks (one function per table/figure).

Table 1/8  — bits sweep (QLoRA bf16 vs GSQ 8/6/5) at fixed rank
Table 2/13 — FP8 (E4M3/E5M2) vs GSE at equal bits: SQNR + proxy fine-tune
Table 5    — MAC-engine area/power analytic model (ratios vs paper)
Table 6    — group-size ablation (32/64/128)
Table 7    — rank sweep (16/64/256)
Fig. 4     — bits x rank Pareto points (accuracy vs memory model)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (PROXY_CFG, PROXY_DATA, csv_row,
                               run_proxy_finetune)
from repro.core.policy import QuantPolicy
from repro.core.gse import quantization_error, gse_bits_per_value
from repro.core.fp8 import fp8_quantization_error


def _tensor_zoo(key):
    """Realistic tensor families: gaussian weights, heavy-tailed
    activations (outlier channels), small-magnitude gradients."""
    ks = jax.random.split(key, 4)
    w = jax.random.normal(ks[0], (256, 1024)) * 0.03
    a = jax.random.normal(ks[1], (256, 1024))
    a = a * (1 + 9.0 * (jax.random.uniform(ks[2], (1, 1024)) > 0.99))
    g = jax.random.normal(ks[3], (256, 1024)) * 1e-3
    return {"weights": w, "acts_outlier": a, "grads": g}


# ---------------------------------------------------------------- table 1
def table1_bits(steps=120):
    rows = []
    policies = [
        ("qlora_4-16-16", QuantPolicy.qlora_bf16(rank=16)),
        ("gsq_4-8-8", QuantPolicy.gsq(8, rank=16)),
        ("gsq_4-6-6", QuantPolicy.gsq(6, rank=16)),
        ("gsq_4-5-5", QuantPolicy.gsq(5, rank=16)),
    ]
    res = {}
    for name, pol in policies:
        m = run_proxy_finetune(pol, steps=steps)
        res[name] = m
        rows.append(csv_row(
            f"table1/{name}", m["us_per_step"],
            f"eval_loss={m['eval_loss']:.4f} acc={m['eval_acc']:.3f} "
            f"best_train={m['best_train_loss']:.4f}"))
    # paper claim: 8-bit GSQ ~ QLoRA. At proxy scale (2L d=96, LR 500x the
    # paper's) GSQ matches QLoRA mid-training, then adapter-quantization
    # oscillation sets in — a regime the paper's 7B @ lr=1e-5 never enters.
    # The claim check therefore compares BEST train loss (early-stopping
    # semantics); the eval rows above show the late-training washout too.
    ratio = res["qlora_4-16-16"]["best_train_loss"] / max(
        res["gsq_4-8-8"]["best_train_loss"], 1e-9)
    rows.append(csv_row(
        "table1/claim_w8_matches_qlora", 0.0,
        f"best_train_loss_ratio(qlora/gsq8)={ratio:.3f} (paper parity=1.0; "
        f"see EXPERIMENTS §Paper-validation note)"))
    return rows


# ---------------------------------------------------------------- table 2
def table2_fp8(steps=120):
    rows = []
    zoo = _tensor_zoo(jax.random.PRNGKey(0))
    for tname, x in zoo.items():
        g8 = float(quantization_error(x, 8)["sqnr_db"])
        g6 = float(quantization_error(x, 6)["sqnr_db"])
        e43 = float(fp8_quantization_error(x, "e4m3")["sqnr_db"])
        e52 = float(fp8_quantization_error(x, "e5m2")["sqnr_db"])
        rows.append(csv_row(
            f"table2/sqnr/{tname}", 0.0,
            f"gse8={g8:.1f}dB gse6={g6:.1f}dB fp8_e4m3={e43:.1f}dB "
            f"fp8_e5m2={e52:.1f}dB"))
    m_fp8 = run_proxy_finetune(QuantPolicy.fp8("e4m3", rank=16), steps=steps)
    m_gse = run_proxy_finetune(QuantPolicy.gsq(8, rank=16), steps=steps)
    rows.append(csv_row(
        "table2/proxy_finetune", m_gse["us_per_step"],
        f"gse8_loss={m_gse['eval_loss']:.4f} "
        f"fp8_loss={m_fp8['eval_loss']:.4f} "
        f"gse_wins={m_gse['eval_loss'] <= m_fp8['eval_loss']}"))
    return rows


# ---------------------------------------------------------------- table 5
# Analytic 7nm MAC model. Components (normalized units, calibrated on the
# paper's own table): int multiplier ~ b^2; int adder ~ b; FP mantissa
# multiplier ~ (m+1)^2; FP alignment shifter + LZA/normalize + exponent
# logic ~ k1*(m+1) + k2*2^?e  -> dominated by shifter/normalizer at low
# precision. GSE adds one shared-exponent add per group (amortized /32).
_PAPER_T5 = {  # format: (area mm^2, power W) from paper Tab. 5
    "fp8_e5m2": (4.36, 2.53), "fp8_e4m3": (5.06, 3.23),
    "fp7_e3m3": (5.05, 2.75), "fp6_e3m2": (3.40, 2.09),
    "gse_int8": (0.85, 1.24), "gse_int7": (0.61, 1.00),
    "gse_int6": (0.47, 0.76), "gse_int5": (0.39, 0.53),
}


def _mac_models():
    """Two-parameter analytic model per quantity:
        INT MAC: alpha * b^2 + gamma      (array multiplier + registers)
        FP MAC:  alpha * (m+2)^2 + K_fp   (same multiplier cell on the
                  significand incl. hidden bit + sign, plus the fixed
                  alignment-shifter / LZA / normalization / exponent
                  datapath that integer MACs do not carry)
    alpha, gamma fit on the paper's three GSE-INT points (8/7/6);
    K_fp on fp8_e4m3. int5 and the three remaining FP rows are HELD OUT —
    the model's prediction quality on them validates the explanation for
    the paper's ~11x area gap."""
    import numpy as np
    fits = {}
    for qi in (0, 1):  # area, power
        bs = np.array([8, 7, 6], float)
        ys = np.array([_PAPER_T5[f"gse_int{int(b)}"][qi] for b in bs])
        A = np.stack([bs ** 2, np.ones_like(bs)], 1)
        alpha, gamma = np.linalg.lstsq(A, ys, rcond=None)[0]
        m_e4m3 = 3
        k_fp = _PAPER_T5["fp8_e4m3"][qi] - alpha * (m_e4m3 + 2) ** 2 - gamma
        fits[qi] = (alpha, gamma, k_fp)
    return fits


def _mac_estimate(fmt: str, fits, qi: int) -> float:
    alpha, gamma, k_fp = fits[qi]
    if fmt.startswith("gse_int"):
        b = int(fmt[-1])
        return alpha * b * b + gamma
    m = int(fmt[-1])
    return alpha * (m + 2) ** 2 + gamma + k_fp


def table5_hardware():
    rows = []
    fits = _mac_models()
    held_out = {"gse_int5", "fp8_e5m2", "fp7_e3m3", "fp6_e3m2"}
    for fmt, (pa, pw) in _PAPER_T5.items():
        ea = _mac_estimate(fmt, fits, 0)
        ep = _mac_estimate(fmt, fits, 1)
        tag = " [held-out]" if fmt in held_out else " [fit]"
        rows.append(csv_row(f"table5/{fmt}", 0.0,
                            f"area_est={ea:.2f}mm2 paper={pa:.2f} | "
                            f"power_est={ep:.2f}W paper={pw:.2f}{tag}"))
    a_ratio = (_mac_estimate("fp8_e4m3", fits, 0)
               / _mac_estimate("gse_int6", fits, 0))
    rows.append(csv_row("table5/claim_area_ratio_fp8_vs_int6", 0.0,
                        f"model={a_ratio:.1f}x paper=10.7x"))
    p_ratio = (_mac_estimate("fp8_e4m3", fits, 1)
               / _mac_estimate("gse_int5", fits, 1))
    rows.append(csv_row("table5/claim_power_ratio_fp8_vs_int5", 0.0,
                        f"model={p_ratio:.1f}x paper="
                        f"{_PAPER_T5['fp8_e4m3'][1] / _PAPER_T5['gse_int5'][1]:.1f}x (~5x claim)"))
    return rows


# ---------------------------------------------------------------- table 6
def table6_group(steps=120):
    rows = []
    x = _tensor_zoo(jax.random.PRNGKey(1))["acts_outlier"]
    for g in (32, 64, 128):
        err = float(quantization_error(x, 6, g)["sqnr_db"])
        m = run_proxy_finetune(QuantPolicy.gsq(6, rank=16, group_size=g),
                               steps=steps)
        rows.append(csv_row(
            f"table6/group{g}", m["us_per_step"],
            f"sqnr={err:.1f}dB eval_loss={m['eval_loss']:.4f} "
            f"acc={m['eval_acc']:.3f} bits/val="
            f"{gse_bits_per_value(6, g):.3f}"))
    return rows


# ---------------------------------------------------------------- table 7
def table7_rank(steps=120):
    rows = []
    for r in (4, 16, 48):        # proxy-scale analogue of 16/64/512
        m = run_proxy_finetune(QuantPolicy.gsq(6, rank=r), steps=steps)
        rows.append(csv_row(
            f"table7/rank{r}", m["us_per_step"],
            f"eval_loss={m['eval_loss']:.4f} acc={m['eval_acc']:.3f}"))
    return rows


# ---------------------------------------------------------------- fig 4
def pareto(steps=100):
    from benchmarks.memory_model import MemRow, estimate_gb, calibrate
    rows = []
    f = calibrate()
    for bits in (5, 6, 8):
        for r, full_r in ((4, 64), (16, 128), (48, 512)):
            m = run_proxy_finetune(QuantPolicy.gsq(bits, rank=r),
                                   steps=steps)
            gb = estimate_gb("llama2_7b",
                             MemRow("x", gse_bits_per_value(bits), bits,
                                    full_r), f)
            rows.append(csv_row(
                f"pareto/b{bits}_r{full_r}", m["us_per_step"],
                f"acc={m['eval_acc']:.3f} mem7b={gb:.2f}GB"))
    return rows
