"""Analytic fine-tuning memory model — reproduces the paper's Mem.(G)
columns (Tab. 1/8) for the LLaMA family.

Components per the paper's setting (batch 16, seq 2048, 8-bit AdamW,
activation storage for backward in the compute format):

  base weights     : NF4 = 4 bits + fp32 absmax / 64 + DQ overhead
  adapters         : bf16 master + fp32 copy + 2x packed GSE moments
                     (b + 5/group bits per moment value — the realized
                     AdamW8bit storage, not an int8+scales spreadsheet)
  activations      : stored GEMM inputs per layer, b_act bits/value
                     (16 for QLoRA, GSE bits + 5/32 shared exp for GSQ)
  gradients        : transient microbatch gradient workspace, b_grad bits
  logits/workspace : fp32 logits on the last microbatch + fixed runtime

The activation/workspace constant is calibrated once on the paper's QLoRA
LLaMA2-7B r64 row (10.73 GB) and then *predicts* every other row.
"""
from __future__ import annotations

import dataclasses

from repro.configs import get_config
from repro.core.gse import gse_bits_per_value

BATCH, SEQ = 16, 2048
GB = 1024 ** 3

# AdamW8bit moment storage: two packed GSE moments at b=8, group=32 —
# matches AdamW8bit.state_nbytes exactly (both are b + 5/group bits/value)
OPT_MOMENT_BITS = 8
OPT_BYTES_PER_PARAM = 2 * gse_bits_per_value(OPT_MOMENT_BITS) / 8


def realized_packed_rows(shape=(2048, 4096), bits=(5, 6, 8), group=32):
    """Measured (not analytic) bytes of live GSE buffers: quantize a real
    weight, bit-pack it, and report device ``nbytes`` of the packed words
    vs the int8 working form and the analytic bits/value. Ratio must be
    ~1.0 — this is the paper's Tab. 1 memory claim as observable bytes."""
    import jax
    import jax.numpy as jnp
    from repro.core.gse import gse_pack, gse_quantize

    w = jax.random.normal(jax.random.PRNGKey(0), shape) * 0.02
    n = w.size
    rows = []
    for b in bits:
        t = gse_quantize(w, b, group)
        p = gse_pack(t)
        jax.block_until_ready(p.mantissa_words)
        unpacked = t.mantissa.nbytes + t.exponent.nbytes
        analytic = gse_bits_per_value(b, group) / 8 * n
        rows.append((f"memory_model/realized_packed/b{b}",
                     p.nbytes,
                     f"unpacked_int8={unpacked} analytic={analytic:.0f} "
                     f"ratio_vs_analytic={p.nbytes / analytic:.4f} "
                     f"saving_vs_int8={1 - p.nbytes / unpacked:.1%}"))
    return rows


def realized_optimizer_rows(shape=(4096, 4096), bits=(5, 8), group=32):
    """Measured (not analytic) packed AdamW moment footprint: init real
    optimizer state for a ``shape`` adapter tree and report
    ``state_nbytes`` (logical packed bytes, BLOCK padding excluded) vs the
    analytic ``2 * (b + 5/group) / 8`` bytes/param and the old
    int8-moments-plus-fp32-block-scales accounting. Ratio vs analytic must
    be ~1.0 — the optimizer row of the paper's bits/value budget as
    observable storage."""
    import jax.numpy as jnp
    from repro.optim.adamw8bit import AdamW8bit

    n = shape[0] * shape[1]
    params = {"w": jnp.zeros(shape, jnp.float32)}
    rows = []
    for b in bits:
        opt = AdamW8bit(m_bits=b, v_bits=b, group=group)
        nbytes = opt.state_nbytes(opt.init(params))
        analytic = 2 * gse_bits_per_value(b, group) / 8 * n
        int8_legacy = 2 * (n + n // 256 * 4)       # int8 + fp32 scales/256
        rows.append((f"memory_model/realized_optimizer/b{b}",
                     nbytes,
                     f"analytic={analytic:.0f} "
                     f"ratio_vs_analytic={nbytes / analytic:.4f} "
                     f"legacy_int8={int8_legacy} "
                     f"saving_vs_int8={1 - nbytes / int8_legacy:.1%}"))
    return rows


def realized_packed_kv_rows(shape=(4, 1, 2048, 4, 128), bits=(4, 8),
                            group=32, tile=512):
    """Measured (not analytic) packed decode-cache footprint: planar-pack a
    real (L, B, S, Kv, D) KV cache into the row-planar word/exponent planes
    the in-place packed decode carries, and report live ``nbytes`` vs the
    bf16 cache and the analytic ``b * ceil(D/32)*32/D + 8/g`` bits/value.

    ``peak_live`` is the decode-step claim: the packed planes plus ONE
    dequantized (B, tile, Kv, D) fp32 attention tile — the only unpacked
    KV bytes that ever exist under the fused kernel (per dequantized
    operand; K and V tiles are live together, hence the 2x). The old
    round-trip path's peak was packed + the ENTIRE cache unpacked.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.qcd import effective_group_size
    from repro.kernels import ops

    l, b, s, kv, d = shape
    g = effective_group_size(d, group)
    rows = []
    for bb in bits:
        k = jax.random.normal(jax.random.PRNGKey(0), shape) * 0.5
        words, exps = ops.quant_pack_kv_rows(k, bb, g)
        jax.block_until_ready(words)
        packed = 2 * (words.nbytes + exps.nbytes)          # k and v planes
        bf16 = 2 * k.astype(jnp.bfloat16).nbytes
        n = 2 * k.size
        analytic = (bb * (-(-d // 32) * 32) / d + 8 / g) / 8 * n
        tile_bytes = 2 * b * min(tile, s) * kv * d * 4     # k + v fp32 tile
        rows.append((f"memory_model/realized_packed_kv/b{bb}",
                     packed,
                     f"bf16={bf16} ratio_vs_bf16={packed / bf16:.3f} "
                     f"analytic={analytic:.0f} "
                     f"ratio_vs_analytic={packed / analytic:.4f} "
                     f"peak_live_fused={packed + tile_bytes} "
                     f"peak_live_roundtrip={packed + bf16}"))
    return rows


def realized_residual_rows(shape=(2048, 1024), bits=(4, 6, 8), group=32):
    """Measured (not analytic) packed QCD backward-residual footprint:
    quantize+pack an activation-residual-shaped tensor exactly as the
    packed ``quantized_matmul`` vjp saves it (fused quantize+pack path,
    ``qcd_xq`` wire format — docs/gse-format.md §5) and report live
    ``nbytes`` vs the bf16 fake-quant residual the legacy path keeps and
    the analytic ``b + 5/group`` bits/value.

    ``ratio_vs_analytic`` is **asserted == 1.0000** (CI runs this script):
    with a 32-aligned last axis the per-row word layout carries zero
    padding, so the realized bytes must hit the paper's bits/value budget
    exactly. ``reduction_vs_bf16`` is the per-tensor residual saving the
    packed training path credits against the paper's ~1.8x total-memory
    claim (Tab. 1: 10.73 -> 5.97 GB at 4-6-6)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    x = jax.random.normal(jax.random.PRNGKey(3), shape)
    n = x.size
    rows = []
    for b in bits:
        p = ops.gse_quantize_pack(x, b, group)
        jax.block_until_ready(p.mantissa_words)
        analytic = gse_bits_per_value(b, group) / 8 * n
        ratio = p.nbytes / analytic
        assert abs(ratio - 1.0) < 1e-9, (
            "realized residual bytes must match the analytic b + 5/group "
            "bits/value exactly (padding-free layout)", p.nbytes, analytic)
        bf16 = 2 * n                                 # legacy residual bytes
        rows.append((f"memory_model/realized_residual/b{b}",
                     p.nbytes,
                     f"bf16_residual={bf16} "
                     f"reduction_vs_bf16={bf16 / p.nbytes:.2f}x "
                     f"analytic={analytic:.0f} "
                     f"ratio_vs_analytic={ratio:.4f}"))
    return rows


@dataclasses.dataclass
class MemRow:
    label: str
    act_bits: float
    grad_bits: float
    rank: int


def _linear_params(cfg) -> int:
    """Params in quantizable linear layers (excludes embeddings/norms)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd \
        + cfg.n_heads * hd * d
    ff = 3 * d * cfg.d_ff if cfg.act in ("silu", "gelu") else 2 * d * cfg.d_ff
    return cfg.n_layers * (attn + ff)


def _adapter_params(cfg, rank: int) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    per_layer = 0
    for i, o in [(d, cfg.n_heads * hd), (d, cfg.n_kv_heads * hd),
                 (d, cfg.n_kv_heads * hd), (cfg.n_heads * hd, d),
                 (d, cfg.d_ff), (d, cfg.d_ff), (cfg.d_ff, d)]:
        per_layer += rank * (i + o)
    return cfg.n_layers * per_layer


def _stored_act_values(cfg) -> int:
    """GEMM-input values saved for backward per microbatch (QCD residuals):
    roughly every linear's input + attention p/v inputs ~ 7 x (B,T,d) +
    2 x (B,T,ff-ish) -> calibrated constant x B x T x d x L."""
    return BATCH * SEQ * cfg.d_model * cfg.n_layers


def estimate_gb(arch: str, row: MemRow, act_factor: float) -> float:
    cfg = get_config(arch)
    n_lin = _linear_params(cfg)
    n_emb = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    base = n_lin * (4 + 32 / 64 + 8 / 4096) / 8 + n_emb * 2
    n_ad = _adapter_params(cfg, row.rank)
    adapters = n_ad * (2 + 4 + OPT_BYTES_PER_PARAM) if row.rank else 0
    acts = _stored_act_values(cfg) * act_factor * row.act_bits / 8
    grads = _stored_act_values(cfg) / cfg.n_layers * row.grad_bits / 8 * 2
    runtime = 0.75 * GB                      # cuda/xla context + code
    return (base + adapters + acts + grads + runtime) / GB


def calibrate(paper_qlora_7b_r64: float = 10.73) -> float:
    """Solve act_factor from the paper's QLoRA LLaMA2-7B r64 row."""
    row = MemRow("4-16-16/16", 16, 16, 64)
    lo, hi = 0.1, 40.0
    for _ in range(60):
        mid = (lo + hi) / 2
        if estimate_gb("llama2_7b", row, mid) < paper_qlora_7b_r64:
            lo = mid
        else:
            hi = mid
    return (lo + hi) / 2


PAPER_ROWS_7B_R64 = {          # paper Tab. 1, LLaMA2-7B, rank 64
    "qlora_4-16-16": (MemRow("qlora", 16, 16, 64), 10.73),
    "gsq_4-8-8": (MemRow("gsq8", gse_bits_per_value(8), 8, 64), 7.28),
    "gsq_4-6-6": (MemRow("gsq6", gse_bits_per_value(6), 6, 64), 5.97),
    "gsq_4-5-5": (MemRow("gsq5", gse_bits_per_value(5), 5, 64), 5.81),
}

PAPER_ROWS_13B_R64 = {
    "qlora_4-16-16": (MemRow("qlora", 16, 16, 64), 17.42),
    "gsq_4-8-8": (MemRow("gsq8", gse_bits_per_value(8), 8, 64), 11.99),
    "gsq_4-6-6": (MemRow("gsq6", gse_bits_per_value(6), 6, 64), 10.89),
    "gsq_4-5-5": (MemRow("gsq5", gse_bits_per_value(5), 5, 64), 10.33),
}


def run(print_csv=True):
    rows = []
    f = calibrate()
    for arch, table in (("llama2_7b", PAPER_ROWS_7B_R64),
                        ("llama2_13b_proxy", None)):
        if table is None:
            continue
        for name, (row, paper_gb) in table.items():
            est = estimate_gb(arch, row, f)
            rows.append((f"memory_model/{arch}/{name}", est, paper_gb))
    # 13B uses scaled config (paper arch): 40L d5120 40H ff13824
    import repro.configs.llama2_7b as l7
    import dataclasses as dc
    cfg13 = dc.replace(l7.config(), name="llama2-13b", n_layers=40,
                       d_model=5120, n_heads=40, n_kv_heads=40, d_ff=13824)
    import repro.configs
    # register temporarily
    import sys
    mod = type(sys)("repro.configs.llama2_13b_proxy")
    mod.config = lambda: cfg13
    sys.modules["repro.configs.llama2_13b_proxy"] = mod
    for name, (row, paper_gb) in PAPER_ROWS_13B_R64.items():
        est = estimate_gb("llama2_13b_proxy", row, f)
        rows.append((f"memory_model/llama2_13b/{name}", est, paper_gb))
    out = []
    for name, est, paper in rows:
        rel = est / paper - 1
        out.append(f"{name},0.0,est={est:.2f}GB paper={paper:.2f}GB "
                   f"rel={rel:+.1%}")
    # headline: the ~50% saving claim at 6 bits
    q = [r for r in rows if "7b/qlora" in r[0]][0]
    g6 = [r for r in rows if "7b/gsq_4-6-6" in r[0]][0]
    out.append(f"memory_model/claim_50pct_saving,0.0,"
               f"model={1 - g6[1] / q[1]:.1%} paper={1 - 5.97 / 10.73:.1%}")
    # realized packed buffers (measured device nbytes, not analytic)
    for name, nbytes, derived in realized_packed_rows():
        out.append(f"{name},{float(nbytes):.1f},{derived}")
    # realized packed optimizer state (AdamW8bit moments on the GSE
    # substrate — the optimizer row of the bits/value budget)
    for name, nbytes, derived in realized_optimizer_rows():
        out.append(f"{name},{float(nbytes):.1f},{derived}")
    # realized packed decode KV cache (row-planar planes the in-place
    # packed decode carries; peak-live = packed + one attention tile)
    for name, nbytes, derived in realized_packed_kv_rows():
        out.append(f"{name},{float(nbytes):.1f},{derived}")
    # realized packed QCD backward residuals (the qcd_xq/qcd_wq word
    # streams the packed training path saves instead of bf16 fake-quant
    # tensors; ratio_vs_analytic asserted == 1.0000)
    for name, nbytes, derived in realized_residual_rows():
        out.append(f"{name},{float(nbytes):.1f},{derived}")
    if print_csv:
        print("\n".join(out))
    return out


if __name__ == "__main__":
    run()
