"""Kernel microbenchmarks: jitted wall time of the quantization hot paths
(value-space jnp simulation, the path the framework executes on CPU) and
derived bytes/value. Pallas-interpret timings are not meaningful wall-clock
(Python interpreter loop) and are reported only as correctness-path info.

Besides the printed csv rows, every measurement is collected as a
structured record (kernel, shape, bits, route, wall_ms) and can be written
to ``BENCH_kernels.json`` with ``--json`` — the persisted perf trajectory
CI uploads per PR (``--smoke`` always writes it).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core.gse import gse_fake_quant, gse_quantize
from repro.core.nf4 import nf4_dequantize, nf4_quantize
from repro.core.qcd import quantized_matmul

BENCH_SCHEMA = "repro/kernel_bench/v1"
DEFAULT_JSON = os.path.normpath(
    os.path.join(os.path.dirname(os.path.abspath(__file__)),
                 os.pardir, "BENCH_kernels.json"))


def _time(fn, *args, iters=20):
    fn(*args)                       # compile + warm
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def write_json(records, path: str, smoke: bool):
    """Write the schema'd trajectory file (one self-describing object; rows
    carry kernel/shape/bits/route/wall_ms so successive check-ins diff)."""
    doc = {"schema": BENCH_SCHEMA, "smoke": bool(smoke),
           "backend": jax.default_backend(), "rows": records}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path


def run(smoke: bool = False, records=None):
    """Full sweep by default; ``smoke`` shrinks shapes/iters to a CI-sized
    pass that still exercises every row (incl. the fused quantize+pack
    kernel, the integer-MAC modes and realized packed bytes) in a few
    seconds. Pass ``records`` (a list) to collect the structured rows."""
    rows = []
    if records is None:
        records = []

    def add(name, us, derived="", *, shape="", bits=None, route="jnp"):
        rows.append(csv_row(name, us, derived))
        records.append({"kernel": name, "shape": shape, "bits": bits,
                        "route": route, "wall_ms": round(us / 1e3, 4),
                        "derived": derived})

    key = jax.random.PRNGKey(0)
    big = (128, 512) if smoke else (512, 2048)
    x = jax.random.normal(key, big)
    w = jax.random.normal(jax.random.PRNGKey(1), big[::-1]) * 0.05
    tag = f"{big[0]}x{big[1]}"

    us = _time(jax.jit(lambda v: gse_fake_quant(v, 6, 32)), x)
    add(f"kernel/gse_fake_quant_{tag}", us,
        f"GBps={x.nbytes / us * 1e6 / 1e9:.2f}", shape=tag, bits=6)
    us = _time(jax.jit(lambda v: gse_quantize(v, 6, 32).mantissa), x)
    add(f"kernel/gse_quantize_{tag}", us,
        f"GBps={x.nbytes / us * 1e6 / 1e9:.2f}", shape=tag, bits=6)
    us = _time(jax.jit(
        lambda a, b: quantized_matmul(a, b, 6, 6, 6, 32)), x, w)
    flops = 2 * big[0] * big[1] * big[0]
    add(f"kernel/qcd_matmul_{tag}x{big[0]}", us,
        f"GFLOPs={flops / us * 1e6 / 1e9:.1f}",
        shape=f"{tag}x{big[0]}", bits=6)
    us = _time(jax.jit(lambda a, b: a @ b), x, w)
    add("kernel/bf16_matmul_baseline", us,
        f"GFLOPs={flops / us * 1e6 / 1e9:.1f}", shape=f"{tag}x{big[0]}")

    t = nf4_quantize(w)
    us = _time(jax.jit(nf4_dequantize), t)
    add(f"kernel/nf4_dequant_{big[1]}x{big[0]}", us,
        f"GBps={w.nbytes / us * 1e6 / 1e9:.2f}",
        shape=f"{big[1]}x{big[0]}", bits=4)

    # flash attention (jnp chunked) vs direct at prefill-ish shape
    from repro.models.attention import (MaskInfo, direct_attention,
                                        flash_attention)
    ks = jax.random.split(key, 3)
    t_attn = 256 if smoke else 2048
    blk = 128 if smoke else 512
    q = jax.random.normal(ks[0], (1, t_attn, 8, 64), jnp.bfloat16)
    kk = jax.random.normal(ks[1], (1, t_attn, 4, 64), jnp.bfloat16)
    vv = jax.random.normal(ks[2], (1, t_attn, 4, 64), jnp.bfloat16)
    info = MaskInfo(causal=True)
    us1 = _time(jax.jit(lambda q, k, v: flash_attention(q, k, v, info,
                                                        blk, blk)),
                q, kk, vv, iters=5)
    us2 = _time(jax.jit(lambda q, k, v: direct_attention(q, k, v, info)),
                q, kk, vv, iters=5)
    add(f"kernel/flash_attn_{t_attn}", us1,
        f"direct_us={us2:.0f} ratio={us2 / us1:.2f}", shape=f"t{t_attn}d64")

    # Pallas interpret-mode correctness path (not wall-representative)
    from repro.kernels import ops
    xs = jax.random.normal(key, (128, 512))
    us = _time(lambda v: ops.gse_quantize(v, 6, 32)[0], xs, iters=3)
    add("kernel/pallas_gse_quant_interpret", us, "correctness-path-only",
        shape="128x512", bits=6, route="kernel-interpret")

    # packed storage: jnp pack/unpack wall time and realized bytes
    from repro.core.gse import gse_pack, gse_quantize as gq, gse_unpack
    t = gq(w.T, 6, 32)                            # (M, K) along K
    us = _time(jax.jit(lambda v: gse_pack(v).mantissa_words), t)
    p = gse_pack(t)
    add(f"kernel/gse_pack_{tag}_b6", us,
        f"GBps={t.mantissa.nbytes / us * 1e6 / 1e9:.2f} "
        f"packed_bytes={p.nbytes} "
        f"int8_bytes={t.mantissa.nbytes + t.exponent.nbytes}",
        shape=tag, bits=6)
    us = _time(jax.jit(lambda v: gse_unpack(v).mantissa), p)
    add(f"kernel/gse_unpack_{tag}_b6", us,
        f"GBps={t.mantissa.nbytes / us * 1e6 / 1e9:.2f}", shape=tag, bits=6)

    # fused quantize+pack vs the two-dispatch storage path. The fused row
    # credits the removed HBM round-trip: the old path writes+reads the
    # int8 mantissa intermediate (~8/6 of the packed payload extra traffic)
    # between its two dispatches; the fused kernel's tile never leaves
    # VMEM unpacked.
    two = jax.jit(lambda v: gse_pack(gq(v, 6, 32)).mantissa_words)
    us2d = _time(two, x)
    int8_roundtrip = 2 * x.size                   # int8 write + read bytes
    add(f"kernel/gse_quant_then_pack_{tag}_b6", us2d,
        f"GBps={x.nbytes / us2d * 1e6 / 1e9:.2f} "
        f"hbm_intermediate_bytes={int8_roundtrip}", shape=tag, bits=6)
    usf = _time(lambda v: ops.gse_quant_pack(v, 6, 32)[0], x, iters=3)
    add(f"kernel/pallas_gse_quant_pack_fused_{tag}_b6", usf,
        f"correctness-path-only hbm_intermediate_bytes=0 "
        f"two_dispatch_us={us2d:.0f}", shape=tag, bits=6,
        route="kernel-interpret")

    # packed-KV decode step: fused tile-local attention + in-place append
    # vs the legacy round-trip (unpack the WHOLE cache, attend, re-pack).
    # Shapes model one decode step against a warm cache; both paths run
    # the jnp/CPU code the serving engine executes here. The fused row's
    # transient unpacked KV is one (B, bk, Kv, D) fp32 tile; the
    # round-trip's is the entire cache (reported as bytes).
    from repro.kernels.flash_attention_packed import (
        dequant_kv_rows, flash_attention_packed_jnp, quant_pack_kv_rows)
    bsz, s_max, kvh, hd, heads = (1, 256, 2, 64, 4) if smoke else \
        (1, 1024, 4, 128, 16)
    bk = 128 if smoke else 512
    kb = 8
    kc = jax.random.normal(jax.random.PRNGKey(20), (bsz, s_max, kvh, hd))
    vc = jax.random.normal(jax.random.PRNGKey(21), (bsz, s_max, kvh, hd))
    kwp, kep = quant_pack_kv_rows(kc, kb)
    vwp, vep = quant_pack_kv_rows(vc, kb)
    qd = jax.random.normal(jax.random.PRNGKey(22), (bsz, 1, heads, hd))
    newk = jax.random.normal(jax.random.PRNGKey(23), (bsz, 1, kvh, hd))
    newv = jax.random.normal(jax.random.PRNGKey(24), (bsz, 1, kvh, hd))
    off = s_max - 1
    shape_kv = f"s{s_max}kv{kvh}d{hd}"

    @jax.jit
    def fused_step(q, kw, ke, vw, ve, nk, nv):
        nw, ne = quant_pack_kv_rows(nk, kb)          # one token's rows
        kw = jax.lax.dynamic_update_slice(kw, nw, (0, off, 0, 0))
        ke = jax.lax.dynamic_update_slice(ke, ne, (0, off, 0, 0))
        nw, ne = quant_pack_kv_rows(nv, kb)
        vw = jax.lax.dynamic_update_slice(vw, nw, (0, off, 0, 0))
        ve = jax.lax.dynamic_update_slice(ve, ne, (0, off, 0, 0))
        return flash_attention_packed_jnp(q, kw, ke, vw, ve, causal=True,
                                          q_offset=off, k_chunk=bk)

    @jax.jit
    def roundtrip_step(q, kw, ke, vw, ve, nk, nv):
        kfull = dequant_kv_rows(kw, ke, hd, jnp.bfloat16)   # WHOLE cache
        vfull = dequant_kv_rows(vw, ve, hd, jnp.bfloat16)
        kfull = jax.lax.dynamic_update_slice(
            kfull, nk.astype(kfull.dtype), (0, off, 0, 0))
        vfull = jax.lax.dynamic_update_slice(
            vfull, nv.astype(vfull.dtype), (0, off, 0, 0))
        o = direct_attention(q, kfull, vfull,
                             MaskInfo(q_offset=off, causal=True))
        kw2, _ = quant_pack_kv_rows(kfull.astype(jnp.float32), kb)  # re-pack
        vw2, _ = quant_pack_kv_rows(vfull.astype(jnp.float32), kb)
        return o, kw2, vw2

    usf = _time(fused_step, qd, kwp, kep, vwp, vep, newk, newv, iters=5)
    usr = _time(roundtrip_step, qd, kwp, kep, vwp, vep, newk, newv, iters=5)
    cache_bytes = 2 * (kwp.nbytes + kep.nbytes)
    tile_bytes = 2 * bsz * bk * kvh * hd * 4
    full_bytes = 2 * kc.astype(jnp.bfloat16).nbytes
    add(f"kernel/packed_kv_decode_fused_s{s_max}_b{kb}", usf,
        f"roundtrip_us={usr:.0f} speedup={usr / usf:.2f} "
        f"packed_bytes={cache_bytes} transient_unpacked={tile_bytes}",
        shape=shape_kv, bits=kb)
    add(f"kernel/packed_kv_decode_roundtrip_s{s_max}_b{kb}", usr,
        f"transient_unpacked={full_bytes}", shape=shape_kv, bits=kb)

    # GQA decode step through the ops dispatcher, both routes, at the
    # shape above (heads/kvh = gqa ratio 2) with a TRACED q_offset — the
    # decode scan's cache["index"]. The kernel row runs the Pallas path
    # (scalar-prefetch offset + GQA grid) in interpret mode: correctness
    # path only, not wall-representative; the fallback row is the jnp
    # CPU serving path. Both run in --smoke so CI exercises the new grids
    # every PR.
    from repro.kernels import ops as _ops
    offt = jnp.asarray(off, jnp.int32)

    def _disp(route, int_mac=False):
        os.environ["REPRO_FAP_ROUTE"] = route

        @jax.jit
        def step(q, kw, ke, vw, ve, o):
            return _ops.flash_attention_packed(q, kw, ke, vw, ve,
                                               causal=True, q_offset=o,
                                               bk=bk, int_mac=int_mac)
        return step

    prev_route = os.environ.get("REPRO_FAP_ROUTE")
    try:
        us_k = _time(_disp("kernel"), qd, kwp, kep, vwp, vep, offt, iters=3)
        assert _ops.last_fap_route()[0] == "kernel"
        us_j = _time(_disp("fallback"), qd, kwp, kep, vwp, vep, offt,
                     iters=3)
        # integer-MAC score GEMM (exact tier: same output bits as fp32) on
        # both routes — the int-vs-fp32 MAC comparison rows.
        us_ki = _time(_disp("kernel", int_mac=True), qd, kwp, kep, vwp, vep,
                      offt, iters=3)
        us_ji = _time(_disp("fallback", int_mac=True), qd, kwp, kep, vwp,
                      vep, offt, iters=3)
    finally:
        if prev_route is None:
            os.environ.pop("REPRO_FAP_ROUTE", None)
        else:
            os.environ["REPRO_FAP_ROUTE"] = prev_route
    add(f"kernel/packed_kv_decode_gqa_kernel_interpret_s{s_max}_b{kb}", us_k,
        f"correctness-path-only scalar-prefetch-offset "
        f"gqa_ratio={heads // kvh} fallback_us={us_j:.0f}",
        shape=shape_kv, bits=kb, route="kernel-interpret")
    add(f"kernel/packed_kv_decode_gqa_fallback_s{s_max}_b{kb}", us_j,
        f"gqa_ratio={heads // kvh} traced-offset", shape=shape_kv, bits=kb,
        route="fallback")
    add(f"kernel/packed_kv_decode_int_mac_kernel_interpret_s{s_max}_b{kb}",
        us_ki, f"correctness-path-only exact-tier fp32_us={us_k:.0f}",
        shape=shape_kv, bits=kb, route="kernel-interpret")
    add(f"kernel/packed_kv_decode_int_mac_fallback_s{s_max}_b{kb}", us_ji,
        f"exact-tier int8-score-GEMM fp32_us={us_j:.0f}",
        shape=shape_kv, bits=kb, route="fallback")

    # fused packed-dequant matmul, interpret mode (correctness path)
    xa = jax.random.normal(key, (128, 512))
    wq = gq(jax.random.normal(jax.random.PRNGKey(9), (256, 512)) * 0.05,
            6, 32)
    pw = gse_pack(wq)
    qa = gq(xa, 6, 32)
    us = _time(lambda m, e: ops.gse_matmul_packed(
        m, e, pw.mantissa_words, wq.exponent, 6, 32,
        bm=128, bn=128, bk=512), qa.mantissa, qa.exponent, iters=3)
    add("kernel/pallas_gse_matmul_packed_interpret", us,
        "correctness-path-only", shape="128x512x256", bits=6,
        route="kernel-interpret")

    # packed-backward QCD step: full fwd+bwd of quantized_matmul with the
    # residuals saved as packed GSE word streams vs the legacy bf16
    # fake-quant residuals. Same jnp/XLA GEMMs on CPU (the simulation
    # path), so the delta is the pack/unpack overhead the packed path pays
    # for its b + 5/group bits/value residual footprint (reported as
    # bytes). Both rows run in --smoke (CI).
    mq, kq, nq = (128, 256, 128) if smoke else (512, 1024, 512)
    xb = jax.random.normal(jax.random.PRNGKey(30), (mq, kq))
    wb = jax.random.normal(jax.random.PRNGKey(31), (kq, nq)) * 0.05
    ct = jax.random.normal(jax.random.PRNGKey(32), (mq, nq))
    shape_q = f"{mq}x{kq}x{nq}"

    def _qcd_step(packed, int_mac=False):
        @jax.jit
        def step(x, w, ct):
            y, vjp = jax.vjp(
                lambda a, b: quantized_matmul(a, b, 6, 6, 6, 32, packed,
                                              None, int_mac),
                x, w)
            dx, dw = vjp(ct)
            return y, dx, dw
        return step

    us_pk = _time(_qcd_step(True), xb, wb, ct, iters=5)
    us_bf = _time(_qcd_step(False), xb, wb, ct, iters=5)
    from repro.core.gse import gse_bits_per_value
    packed_bytes = int(gse_bits_per_value(6, 32) / 8 * (xb.size + wb.size))
    bf16_bytes = 2 * (xb.size + wb.size)
    add(f"kernel/qcd_bwd_packed_residuals_{mq}x{kq}x{nq}", us_pk,
        f"bf16_residual_us={us_bf:.0f} residual_bytes={packed_bytes} "
        f"bf16_residual_bytes={bf16_bytes} "
        f"bytes_saving={1 - packed_bytes / bf16_bytes:.1%}",
        shape=shape_q, bits=6, route=ops.last_qcd_route("dx")[0])
    add(f"kernel/qcd_bwd_bf16_residuals_{mq}x{kq}x{nq}", us_bf,
        f"residual_bytes={bf16_bytes}", shape=shape_q, bits=6)

    # transposed-contraction / token-contraction packed matmuls (the dX/dW
    # backward kernels), interpret mode (correctness path), fp32 tile MACs
    # vs the realigned int32 MAC mode (bounded tier) on the same operands.
    dyq = gq(jax.random.normal(jax.random.PRNGKey(33), (128, 256)), 6, 32)
    pdy = gse_pack(dyq)
    xq2 = gq(jax.random.normal(jax.random.PRNGKey(34), (128, 512)), 6, 32)
    px2 = gse_pack(xq2)
    wq2 = gq(jax.random.normal(jax.random.PRNGKey(35), (256, 512)) * 0.05,
             6, 32)
    pw2 = gse_pack(wq2)

    def _nt(int_mac):
        return _time(lambda aw, bw: ops.gse_matmul_packed_nt(
            aw, dyq.exponent, bw, wq2.exponent, 6, 6, 32, 32,
            bm=128, bn=256, bk=128, int_mac=int_mac),
            pdy.mantissa_words, pw2.mantissa_words, iters=3)

    def _tn(int_mac):
        return _time(lambda aw, bw: ops.gse_matmul_packed_tn(
            aw, xq2.exponent, bw, dyq.exponent, 6, 6, 32, 32,
            bm=128, bn=128, bk=128, int_mac=int_mac),
            px2.mantissa_words, pdy.mantissa_words, iters=3)

    us_nt, us_tn = _nt(False), _tn(False)
    add("kernel/pallas_gse_matmul_packed_nt_interpret", us_nt,
        "correctness-path-only dX-shaped", shape="128x256x512", bits=6,
        route="kernel-interpret")
    add("kernel/pallas_gse_matmul_packed_tn_interpret", us_tn,
        "correctness-path-only dW-shaped", shape="128x512x256", bits=6,
        route="kernel-interpret")
    us = _nt(True)
    add("kernel/pallas_gse_matmul_packed_nt_int_mac_interpret", us,
        f"correctness-path-only dX-shaped bounded-tier fp32_us={us_nt:.0f}",
        shape="128x256x512", bits=6, route="kernel-interpret")
    us = _tn(True)
    add("kernel/pallas_gse_matmul_packed_tn_int_mac_interpret", us,
        f"correctness-path-only dW-shaped bounded-tier fp32_us={us_tn:.0f}",
        shape="128x512x256", bits=6, route="kernel-interpret")

    # plane-prefix views over one 8-bit store: unpack / fused matmul /
    # packed-KV attention read only the first b planes (with_bits(b) /
    # kv_active_bits=b). The hbm_words_bytes column is what a narrow read
    # actually fetches — b/8 of the stored mantissa stream, because the
    # view is a word-prefix slice, not a re-quantized copy. b=8 is the
    # identity view (same words, zero shift): the no-narrowing baseline.
    from repro.core.gse import gse_unpack as core_unpack
    p8 = gse_pack(gq(w.T, 8, 32))                 # (M, K) along K, 8-bit
    wq8 = gq(jax.random.normal(jax.random.PRNGKey(10), (256, 512)) * 0.05,
             8, 32)
    pw8t = gse_pack(wq8)                          # logical (N=256, K=512)
    stored_mw = p8.mantissa_words.nbytes
    stored_ww = pw8t.mantissa_words.nbytes
    stored_kv = kwp.nbytes + vwp.nbytes
    for ab in (4, 6, 8):
        view_mw = stored_mw * ab // 8
        us = _time(jax.jit(
            lambda p, b=ab: core_unpack(p.with_bits(b)).mantissa), p8)
        add(f"kernel/plane_prefix_unpack_{tag}_b{ab}of8", us,
            f"GBps={view_mw / us * 1e6 / 1e9:.2f} "
            f"hbm_words_bytes={view_mw} stored_bytes={stored_mw}",
            shape=tag, bits=ab)
        us = _time(lambda a, b=ab: ops.gse_linear_packed(
            a, pw8t.with_bits(b), bm=128, bn=128, bk=512), xa, iters=3)
        add(f"kernel/plane_prefix_matmul_interpret_b{ab}of8", us,
            f"correctness-path-only hbm_words_bytes={stored_ww * ab // 8} "
            f"stored_bytes={stored_ww}", shape="128x512x256", bits=ab,
            route="kernel-interpret")

    prev_route = os.environ.get("REPRO_FAP_ROUTE")
    try:
        os.environ["REPRO_FAP_ROUTE"] = "fallback"
        for ab in (4, 6, 8):
            @jax.jit
            def step(q, kw, ke, vw, ve, o, b=ab):
                return _ops.flash_attention_packed(
                    q, kw, ke, vw, ve, causal=True, q_offset=o, bk=bk,
                    kv_active_bits=b)
            us = _time(step, qd, kwp, kep, vwp, vep, offt, iters=3)
            add(f"kernel/plane_prefix_attn_fallback_s{s_max}_b{ab}of8", us,
                f"hbm_words_bytes={stored_kv * ab // 8} "
                f"stored_bytes={stored_kv}", shape=shape_kv, bits=ab,
                route="fallback")
    finally:
        if prev_route is None:
            os.environ.pop("REPRO_FAP_ROUTE", None)
        else:
            os.environ["REPRO_FAP_ROUTE"] = prev_route
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized pass: small shapes, every row exercised; "
                         "also writes the JSON trajectory file")
    ap.add_argument("--json", nargs="?", const=DEFAULT_JSON, default=None,
                    metavar="PATH",
                    help=f"write structured rows (default {DEFAULT_JSON})")
    args = ap.parse_args()
    recs = []
    print("\n".join(run(smoke=args.smoke, records=recs)))
    json_path = args.json or (DEFAULT_JSON if args.smoke else None)
    if json_path:
        print(f"wrote {write_json(recs, json_path, args.smoke)}")
