"""Kernel microbenchmarks: jitted wall time of the quantization hot paths
(value-space jnp simulation, the path the framework executes on CPU) and
derived bytes/value. Pallas-interpret timings are not meaningful wall-clock
(Python interpreter loop) and are reported only as correctness-path info.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.core.gse import gse_fake_quant, gse_quantize
from repro.core.nf4 import nf4_dequantize, nf4_quantize
from repro.core.qcd import quantized_matmul


def _time(fn, *args, iters=20):
    fn(*args)                       # compile + warm
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512, 2048))
    w = jax.random.normal(jax.random.PRNGKey(1), (2048, 512)) * 0.05

    us = _time(jax.jit(lambda v: gse_fake_quant(v, 6, 32)), x)
    rows.append(csv_row("kernel/gse_fake_quant_512x2048", us,
                        f"GBps={x.nbytes / us * 1e6 / 1e9:.2f}"))
    us = _time(jax.jit(lambda v: gse_quantize(v, 6, 32).mantissa), x)
    rows.append(csv_row("kernel/gse_quantize_512x2048", us,
                        f"GBps={x.nbytes / us * 1e6 / 1e9:.2f}"))
    us = _time(jax.jit(
        lambda a, b: quantized_matmul(a, b, 6, 6, 6, 32)), x, w)
    flops = 2 * 512 * 2048 * 512
    rows.append(csv_row("kernel/qcd_matmul_512x2048x512", us,
                        f"GFLOPs={flops / us * 1e6 / 1e9:.1f}"))
    us = _time(jax.jit(lambda a, b: a @ b), x, w)
    rows.append(csv_row("kernel/bf16_matmul_baseline", us,
                        f"GFLOPs={flops / us * 1e6 / 1e9:.1f}"))

    t = nf4_quantize(w)
    us = _time(jax.jit(nf4_dequantize), t)
    rows.append(csv_row("kernel/nf4_dequant_2048x512", us,
                        f"GBps={w.nbytes / us * 1e6 / 1e9:.2f}"))

    # flash attention (jnp chunked) vs direct at prefill-ish shape
    from repro.models.attention import (MaskInfo, direct_attention,
                                        flash_attention)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 2048, 8, 64), jnp.bfloat16)
    kk = jax.random.normal(ks[1], (1, 2048, 4, 64), jnp.bfloat16)
    vv = jax.random.normal(ks[2], (1, 2048, 4, 64), jnp.bfloat16)
    info = MaskInfo(causal=True)
    us1 = _time(jax.jit(lambda q, k, v: flash_attention(q, k, v, info,
                                                        512, 512)),
                q, kk, vv, iters=5)
    us2 = _time(jax.jit(lambda q, k, v: direct_attention(q, k, v, info)),
                q, kk, vv, iters=5)
    rows.append(csv_row("kernel/flash_attn_2k", us1,
                        f"direct_us={us2:.0f} ratio={us2 / us1:.2f}"))

    # Pallas interpret-mode correctness path (not wall-representative)
    from repro.kernels import ops
    xs = jax.random.normal(key, (128, 512))
    us = _time(lambda v: ops.gse_quantize(v, 6, 32)[0], xs, iters=3)
    rows.append(csv_row("kernel/pallas_gse_quant_interpret", us,
                        "correctness-path-only"))

    # packed storage: jnp pack/unpack wall time and realized bytes
    from repro.core.gse import gse_pack, gse_quantize as gq, gse_unpack
    t = gq(w.T, 6, 32)                            # (512, 2048) along K
    us = _time(jax.jit(lambda v: gse_pack(v).mantissa_words), t)
    p = gse_pack(t)
    rows.append(csv_row(
        "kernel/gse_pack_512x2048_b6", us,
        f"GBps={t.mantissa.nbytes / us * 1e6 / 1e9:.2f} "
        f"packed_bytes={p.nbytes} int8_bytes={t.mantissa.nbytes + t.exponent.nbytes}"))
    us = _time(jax.jit(lambda v: gse_unpack(v).mantissa), p)
    rows.append(csv_row("kernel/gse_unpack_512x2048_b6", us,
                        f"GBps={t.mantissa.nbytes / us * 1e6 / 1e9:.2f}"))

    # fused packed-dequant matmul, interpret mode (correctness path)
    xa = jax.random.normal(key, (128, 512))
    wq = gq(jax.random.normal(jax.random.PRNGKey(9), (256, 512)) * 0.05,
            6, 32)
    pw = gse_pack(wq)
    qa = gq(xa, 6, 32)
    us = _time(lambda m, e: ops.gse_matmul_packed(
        m, e, pw.mantissa_words, wq.exponent, 6, 32,
        bm=128, bn=128, bk=512), qa.mantissa, qa.exponent, iters=3)
    rows.append(csv_row("kernel/pallas_gse_matmul_packed_interpret", us,
                        "correctness-path-only"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
