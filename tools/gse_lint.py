#!/usr/bin/env python
"""CI entry point for the GSE parity-contract linter.

Thin wrapper so the gate runs without an installed package:
inserts ``src/`` on sys.path and delegates to :mod:`repro.analysis.lint`.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.analysis.lint import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
