#!/usr/bin/env python
"""Docs link checker (CI): every relative markdown link in README.md and
docs/*.md must resolve to an existing file, every `#anchor` must match a
heading in the target (GitHub slug rules), and the core docs pages must be
reachable from README. Exits non-zero with a list of broken links.

    python tools/check_docs_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
# docs/ pages that must be linked from the README
REQUIRED_FROM_README = ("docs/gse-format.md", "docs/architecture.md",
                        "docs/benchmarks.md", "docs/static-analysis.md")


def github_slug(heading: str) -> str:
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)
    return h.replace(" ", "-")


def anchors_of(path: Path) -> set:
    return {github_slug(m) for m in HEADING_RE.findall(
        path.read_text(encoding="utf-8"))}


def check_file(path: Path, errors: list):
    text = path.read_text(encoding="utf-8")
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        ref, _, anchor = target.partition("#")
        dest = path if not ref else (path.parent / ref).resolve()
        if ref and not dest.exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md":
            if github_slug(anchor) not in anchors_of(dest):
                errors.append(f"{path.relative_to(ROOT)}: missing anchor "
                              f"-> {target}")


def main() -> int:
    errors = []
    readme = ROOT / "README.md"
    pages = [readme] + sorted((ROOT / "docs").glob("*.md"))
    for page in pages:
        check_file(page, errors)
    readme_text = readme.read_text(encoding="utf-8")
    for req in REQUIRED_FROM_README:
        if req not in readme_text:
            errors.append(f"README.md: does not link {req}")
    if errors:
        print("\n".join(errors))
        return 1
    print(f"docs links OK ({len(pages)} pages checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
