"""Synthetic instruction-tuning data pipeline.

Deterministic, seeded, resumable. Emulates the paper's Alpaca-style SFT
setup: (instruction, response) pairs packed into fixed-length sequences with
a loss mask over the instruction span. The synthetic task family is
*learnable* (sorting / reversal / copy / arithmetic over token spans) so the
proxy benchmarks show real loss separation between quantization policies.

Production posture:
  * per-process sharding: each data-parallel host reads a disjoint
    index-striped slice (``host_id``/``num_hosts``),
  * step-exact resume: the stream is a pure function of (seed, step), so
    restart-from-checkpoint replays nothing and skips nothing,
  * background prefetch thread with a bounded queue.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

PAD, BOS, SEP, EOS = 0, 1, 2, 3
N_SPECIAL = 4


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int = 1000
    seq_len: int = 256
    global_batch: int = 8
    seed: int = 1234
    task_mix: tuple = ("copy", "reverse", "sort", "add")
    min_span: int = 4
    max_span: int = 24
    host_id: int = 0
    num_hosts: int = 1
    prefetch: int = 2


def _gen_example(rng: np.random.Generator, cfg: DataConfig):
    """One (tokens, loss_mask) instruction/response pair."""
    task = cfg.task_mix[rng.integers(len(cfg.task_mix))]
    n = int(rng.integers(cfg.min_span, cfg.max_span + 1))
    lo = N_SPECIAL
    hi = cfg.vocab
    span = rng.integers(lo, hi, size=n)
    if task == "copy":
        resp = span
    elif task == "reverse":
        resp = span[::-1]
    elif task == "sort":
        resp = np.sort(span)
    else:  # add: elementwise +1 mod vocab range
        resp = lo + (span - lo + 1) % (hi - lo)
    toks = np.concatenate([[BOS], span, [SEP], resp, [EOS]])
    mask = np.concatenate([np.zeros(n + 2), np.ones(len(resp) + 1)])
    return toks.astype(np.int32), mask.astype(np.float32)


def _pack_sequence(rng: np.random.Generator, cfg: DataConfig):
    """Pack examples into one (seq_len+1,) token row + loss mask."""
    toks = np.full(cfg.seq_len + 1, PAD, np.int32)
    mask = np.zeros(cfg.seq_len + 1, np.float32)
    pos = 0
    while pos < cfg.seq_len + 1:
        t, m = _gen_example(rng, cfg)
        take = min(len(t), cfg.seq_len + 1 - pos)
        toks[pos: pos + take] = t[:take]
        mask[pos: pos + take] = m[:take]
        pos += take
    return toks, mask


def batch_at_step(cfg: DataConfig, step: int) -> dict:
    """Pure function (seed, step) -> global batch. Hosts materialize only
    their stripe; here (single host sim) we return the whole batch.

    Returns {"tokens": (B, T), "labels": (B, T), "loss_mask": (B, T)}.
    """
    b = cfg.global_batch
    rows_t, rows_m = [], []
    lo = cfg.host_id * b // cfg.num_hosts
    hi = (cfg.host_id + 1) * b // cfg.num_hosts
    for row in range(lo, hi):
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, row]))
        t, m = _pack_sequence(rng, cfg)
        rows_t.append(t)
        rows_m.append(m)
    toks = np.stack(rows_t)
    mask = np.stack(rows_m)
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
        "loss_mask": mask[:, 1:],
    }


class PrefetchingLoader:
    """Bounded-queue background prefetch over batch_at_step."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            batch = batch_at_step(self.cfg, s)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        s, batch = self._q.get()
        self.step = s + 1
        return batch

    def close(self):
        self._stop.set()
