"""NF4 (4-bit NormalFloat) + Double Quantization, per QLoRA.

GSQ-Tuning stores the frozen base-model weights in NF4 (the paper builds on
QLoRA: "all weights are quantized as NF4 firstly", Tab. 1 caption) and
dequantizes them blockwise before the GSE-quantized GEMM.

NF4 codebook: 16 quantiles of N(0,1) normalized to [-1, 1] with an exact zero
(Dettmers et al. 2023, App. E). Per-block absmax scales (block=64); Double
Quantization stores the fp32 absmax scales themselves quantized to int8 with
one fp32 scale per 256 blocks.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Exact NF4 codebook from the QLoRA reference implementation.
NF4_CODE = np.array([
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0,
], dtype=np.float32)

BLOCK = 64            # QLoRA first-level block size
DQ_BLOCK = 256        # second-level (double-quant) block of scales


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class NF4Tensor:
    """Frozen base weight in NF4 with double-quantized scales.

    codes: uint8 (one code per value, 4 significant bits), flat (n,).
    qscale: int8 quantized absmax per block, (n // BLOCK,).
    qscale_scale: fp32 scale of the scales, (n // BLOCK // DQ_BLOCK,).
    qscale_mean: fp32 scalar mean subtracted before scale quantization.
    shape: original weight shape.
    """
    codes: jax.Array
    qscale: jax.Array
    qscale_scale: jax.Array
    qscale_mean: jax.Array
    shape: tuple

    def tree_flatten(self):
        return ((self.codes, self.qscale, self.qscale_scale,
                 self.qscale_mean), (self.shape,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, aux[0])

    def dequantize(self, dtype=jnp.bfloat16) -> jax.Array:
        return nf4_dequantize(self, dtype)

    def nbytes_packed(self) -> int:
        n = int(np.prod(self.shape))
        nb = n // BLOCK
        return n // 2 + nb + 4 * (max(nb // DQ_BLOCK, 1)) + 4


def _pad_to(x: jax.Array, mult: int) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x


@partial(jax.jit, static_argnames=())
def _quantize_flat(w: jax.Array):
    wf = _pad_to(jnp.asarray(w, jnp.float32).reshape(-1), BLOCK)
    blocks = wf.reshape(-1, BLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=-1)                    # (nb,)
    safe = jnp.where(absmax > 0, absmax, 1.0)
    normed = blocks / safe[:, None]
    # nearest codebook entry
    code = jnp.asarray(NF4_CODE)
    idx = jnp.argmin(jnp.abs(normed[..., None] - code), axis=-1).astype(jnp.uint8)
    # Double quantization of absmax: subtract mean, int8 absmax-quant per 256.
    mean = jnp.mean(absmax)
    centered = absmax - mean
    cpad = _pad_to(centered, DQ_BLOCK).reshape(-1, DQ_BLOCK)
    smax = jnp.max(jnp.abs(cpad), axis=-1)
    ssafe = jnp.where(smax > 0, smax, 1.0)
    qs = jnp.clip(jnp.round(cpad / ssafe[:, None] * 127), -127, 127
                  ).astype(jnp.int8).reshape(-1)[: absmax.shape[0]]
    return idx.reshape(-1), qs, (ssafe / 127).astype(jnp.float32), mean


def nf4_quantize(w: jax.Array) -> NF4Tensor:
    codes, qs, ss, mean = _quantize_flat(w)
    n = int(np.prod(w.shape))
    if n % BLOCK == 0:
        # keep the weight's own shape so TP/FSDP sharding rules for the
        # weight apply verbatim to its codes (no flat-layout reshard).
        codes = codes.reshape(w.shape)
    return NF4Tensor(codes, qs, ss, mean, tuple(w.shape))


@partial(jax.jit, static_argnames=("dtype",))
def nf4_dequantize(t: NF4Tensor, dtype=jnp.bfloat16) -> jax.Array:
    n = int(np.prod(t.shape))
    code = jnp.asarray(NF4_CODE)
    nb = t.qscale.shape[0]
    qs = _pad_to(t.qscale.astype(jnp.float32), DQ_BLOCK).reshape(-1, DQ_BLOCK)
    absmax = (qs * t.qscale_scale[:, None]).reshape(-1)[:nb] + t.qscale_mean
    # Knob read through the shared 1/0/auto registry (repro.kernels.ops) —
    # lazy import: kernels.ops transitively imports this module, and the
    # read happens at trace time, long after both modules initialize.
    from repro.kernels.ops import nf4_flat_dequant
    if (t.codes.shape == t.shape and t.shape
            and t.shape[-1] % BLOCK == 0
            and not nf4_flat_dequant()):
        # Shape-preserving path: split only the minor-most dim into 64-value
        # blocks (row-major flat blocks == contiguous row spans). A flat
        # (-1, 64) reshape of a TP-sharded weight defeats GSPMD and costs a
        # full-weight all-gather per dequant (§Perf iteration 4). The fat
        # LUT/scale chain runs in the target dtype (bf16) — the codebook is
        # exactly representable to bf16 rounding and absmax carries the
        # dynamic range (§Perf iteration 9).
        vals = code.astype(dtype)[t.codes]
        blocks = vals.reshape(*t.shape[:-1], t.shape[-1] // BLOCK, BLOCK)
        am = absmax.reshape(*t.shape[:-1], t.shape[-1] // BLOCK)
        return (blocks * am[..., None].astype(dtype)).reshape(t.shape)
    vals = code[t.codes].reshape(-1)                               # (npad,)
    out = (vals.reshape(-1, BLOCK) * absmax[:, None]).reshape(-1)[:n]
    return out.reshape(t.shape).astype(dtype)


def nf4_fake_quant(w: jax.Array, dtype=None) -> jax.Array:
    """Round-trip NF4 quantization (simulation primitive for model init)."""
    return nf4_dequantize(nf4_quantize(w), dtype or w.dtype)
