"""Group-Shared Exponents Integer (GSE) format.

The paper's core numeric format: a group of ``group_size`` contiguous values
along the matmul contraction axis shares one 5-bit exponent; each value keeps
a signed integer mantissa of ``bits`` total bits (symmetric range, no implicit
leading one):

    x_i ~= m_i * 2^(e_g)          m_i in [-(2^(b-1)-1), 2^(b-1)-1]

Storage is ``N*b + E`` bits per group versus ``N*(E+M+1)`` for FP — the
shared exponent amortizes to ~0.16 bits/value at N=32.

This module is the *value-space* reference implementation used throughout the
framework (models, QCD matmul, gradient compression). The Pallas kernels in
``repro.kernels`` implement the same math with explicit VMEM tiling and are
validated against this module.

Conventions
-----------
* Quantization always happens along the **last** axis of the tensor handed in
  (callers transpose so the contraction axis is last).
* The exponent is stored as int8 holding the *unbiased* exponent value in
  [-EXP_BIAS, EXP_BIAS - 1] (5-bit field, bias 16).
* Mantissas are stored in int8 regardless of ``bits`` (2..8) in the
  *working* representation (:class:`GSETensor`); values are clamped to the
  b-bit symmetric range.
* The *storage* representation (:class:`PackedGSETensor`) really packs the
  b-bit mantissas and 5-bit exponents into uint32 words so live buffer
  ``nbytes`` matches :func:`gse_bits_per_value` (the paper's memory claim as
  observable bytes, not a spreadsheet).

Packed wire/storage format (v2: plane-major, MSB-first)
-------------------------------------------------------
Mantissas are packed along the **last axis** in chunks of 32 values; every
leading axis is preserved, so a ``(N, K)`` weight packs to a
``(N, bits * ceil(K/32))`` uint32 array that Pallas kernels can tile with
ordinary BlockSpecs. When the last axis is *not* a multiple of 32 (e.g. a
KV-cache head_dim of 8), the fully flattened value stream is packed into a
1-D word array instead — at most 31 values of zero padding total, keeping
storage at ~``bits`` bits/value for any shape. The choice is determined by
the stored logical shape, so no extra metadata is needed to unpack.

The layout is **bit-planar and plane-major**: plane ``p`` holds mantissa
bit ``bits - 1 - p`` (plane 0 = MSB) of all values, with value ``i`` of a
chunk at bit position (lane) ``i`` of the plane's uint32 word. Words are
ordered *plane-major* along the packed axis: word index
``p * ceil(K/32) + c`` is plane ``p`` of chunk ``c``, i.e. the packed axis
is a ``(bits, chunks)`` array flattened row-major. Mantissas are stored
offset-binary with offset ``2^(bits-1)``: ``u = m + 2^(bits-1)`` in
``[2^(bits-1) - qmax, 2^(bits-1) + qmax]``, so no sign handling is needed
in the shift/mask unpack. The planar layout keeps every b-bit field
word-aligned (no field ever straddles a word), which is what makes the
on-chip unpack a pure vectorized shift/mask — no gathers.

The MSB-first plane-major order + power-of-two offset make the format
**prefix-truncatable** (docs/gse-format.md §7): the first
``b * ceil(K/32)`` words of a ``stored``-bit stream are, verbatim, a valid
``b``-bit plane-major stream whose decoded mantissas are the
floor-truncation ``m >> (stored - b)`` of the stored mantissas — because
``(m + 2^(s-1)) >> t == (m >> t) + 2^(b-1)`` exactly for ``t = s - b``.
Reading a prefix is therefore a *view* (:meth:`PackedGSETensor.with_bits`),
not a re-quantization; consumers compensate by adding ``t`` to the shared
exponents (``(m >> t) * 2^(e+t) ~= m * 2^e``). Truncated mantissas live in
the *asymmetric* range ``[-2^(b-1), 2^(b-1) - 1]`` (one step past ``-qmax``
when the floor lands there), and shifted working exponents may exceed
``EXP_MAX`` — both are fine in the int8/fp32 working form but must never be
re-packed through the 5-bit exponent field without re-quantizing.

Exponents are biased to ``[0, 31]`` (``u = e + EXP_BIAS``), flattened to
1-D, and packed with the identical chunk-of-32 / 5-plane plane-major
scheme.

Word endianness: lane ``i`` is bit ``i`` counting from the LSB of the
uint32 (little-endian within the word); words are stored in increasing
chunk order within a plane and increasing plane order (MSB plane first)
along the axis. A serialized stream of the little-endian uint32 words is
therefore fully specified and portable.

Converters: :func:`gse_pack` / :func:`gse_unpack` (jnp, any backend) are
bit-exact inverses; ``repro.kernels.gse_unpack`` and the fused
``repro.kernels.gse_matmul.gse_matmul_packed_pallas`` implement the same
shift/mask math in Pallas VMEM tiles.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

EXP_BITS = 5                 # fixed by the paper (Sec. 2.2)
EXP_BIAS = 16                # unbiased exponent range [-16, 15]
EXP_MIN = -EXP_BIAS
EXP_MAX = EXP_BIAS - 1
DEFAULT_GROUP = 32           # paper's default group size (Tab. 6)


def qmax_for_bits(bits: int) -> int:
    """Largest mantissa magnitude for a b-bit symmetric signed integer."""
    if not 2 <= bits <= 8:
        raise ValueError(f"GSE bits must be in [2, 8], got {bits}")
    return (1 << (bits - 1)) - 1


def mantissa_offset(bits: int) -> int:
    """Offset-binary bias of the packed mantissa field: ``2^(bits-1)``.

    A power of two (NOT ``qmax``) so that plane-prefix truncation commutes
    with the offset: ``(m + 2^(s-1)) >> t == (m >> t) + 2^(b-1)`` for
    ``b = s - t`` — the identity that makes :meth:`PackedGSETensor.with_bits`
    a pure word slice. Every pack/unpack body (core, kernels, oracles) must
    use this one definition.
    """
    return 1 << (bits - 1)


def mantissa_abs_max(bits: int, truncated: bool = False) -> int:
    """Largest |mantissa| a b-bit stream can decode to.

    Natively packed streams are symmetric (``qmax``); plane-prefix views
    floor-truncate and can land on ``-2^(b-1)`` (= ``-(qmax+1)``), so
    accumulator-depth guards over possibly-truncated operands must budget
    one extra step of magnitude.
    """
    return qmax_for_bits(bits) + (1 if truncated else 0)


def plane_prefix_words(words, stored_bits: int, b: int, chunks: int = None):
    """Slice the first ``b`` planes of a plane-major packed word axis.

    ``words`` (..., stored_bits * chunks) uint32 -> (..., b * chunks): the
    zero-copy plane-prefix read underlying
    :meth:`PackedGSETensor.with_bits`. This is THE one sanctioned raw word
    slice — every other module must go through it (or through ``with_bits``)
    so the prefix semantics live in a single place (gse-lint R5).
    """
    if not 2 <= b <= stored_bits:
        raise ValueError(
            f"prefix bits {b} outside [2, stored_bits={stored_bits}]")
    if chunks is None:
        chunks = words.shape[-1] // stored_bits
    return words[..., : b * chunks]


def effective_group_size(k: int, group_size: int) -> int:
    """Largest divisor of ``k`` that is <= group_size.

    LoRA ranks (16, 32, ...) can be smaller than the group size; grouping then
    degrades gracefully to per-``k`` granularity (more exponents, never less
    precision). Lives here (not qcd.py) so kernels can import it without
    pulling in the training-path module.
    """
    g = min(group_size, k)
    while k % g != 0:
        g -= 1
    return g


def exp2_int(e: jax.Array) -> jax.Array:
    """Exact fp32 ``2**e`` for integer ``e`` via IEEE-754 bit assembly.

    XLA's ``exp2`` lowers through ``exp(x * ln2)`` on some backends and can
    be an ulp off even for integer arguments — fatal for the bit-exact
    matmul parity contract, where the scale must be an exact power of two.
    Valid for ``e`` in [-126, 127] (normal range); GSE uses [-32, 30].
    """
    biased = (e.astype(jnp.int32) + 127) << 23
    return jax.lax.bitcast_convert_type(biased, jnp.float32)


def as_f32_exact(x: jax.Array) -> jax.Array:
    """Upcast to fp32 so the quantizer sees exactly the values ``x.dtype``
    declares.

    XLA's excess-precision folding (on by default) can elide an
    ``f32 -> bf16 -> f32`` convert pair, letting a downstream quantizer
    observe a GEMM output *finer* than bf16 — and whether the fold fires
    depends on the surrounding fusion, so the same quantize math in two
    different programs can round the same logical tensor differently
    (ties split the other way). For bf16 the fp32 view is therefore
    CONSTRUCTED from the bf16 bit pattern (shift into the high half), which
    forces the rounding to materialize in every program; other dtypes take
    the ordinary convert (fp32 input has no excess precision to lose).
    """
    if x.dtype == jnp.bfloat16:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
        return jax.lax.bitcast_convert_type(u << 16, jnp.float32)
    return x.astype(jnp.float32)


def ceil_log2(y: jax.Array) -> jax.Array:
    """Exact ``ceil(log2(y))`` for positive finite fp32, as int32, via the
    IEEE-754 bit pattern: a normal ``y = 2^e * 1.m`` has ceil-log2 ``e``
    when the mantissa bits are zero and ``e + 1`` otherwise.

    XLA's ``log2`` is an approximation whose ulp error *varies with fusion
    context*: the same ``ceil(log2(amax/qmax))`` traced in two different
    programs can land on opposite sides of an exact power of two and flip
    the shared exponent by one — which is fatal for the packed-residual /
    fake-quant A/B parity contract (repro.core.qcd). Every shared-exponent
    computation in the framework goes through this helper so the group
    exponent is a pure function of the value, not of the surrounding HLO.
    (Subnormal ``y`` returns ~-126; the GSE clip to EXP_MIN covers it.)
    """
    bits = jax.lax.bitcast_convert_type(jnp.asarray(y, jnp.float32),
                                        jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    return jnp.where((bits & 0x7FFFFF) == 0, e, e + 1).astype(jnp.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class GSETensor:
    """A tensor held in GSE format.

    Attributes:
      mantissa: int8, same shape as the source tensor.
      exponent: int8, shape = source shape with last dim ``// group_size``.
      bits: mantissa bit-width (metadata).
      group_size: values per shared exponent (metadata).
    """
    mantissa: jax.Array
    exponent: jax.Array
    bits: int
    group_size: int

    @property
    def shape(self):
        return self.mantissa.shape

    @property
    def dtype(self):
        return jnp.int8

    def tree_flatten(self):
        return (self.mantissa, self.exponent), (self.bits, self.group_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return gse_dequantize(self, dtype)

    def nbytes_packed(self) -> int:
        """True packed size in bytes (b-bit mantissas + 5-bit exponents)."""
        n = int(np.prod(self.mantissa.shape))
        g = int(np.prod(self.exponent.shape))
        return (n * self.bits + g * EXP_BITS + 7) // 8


# ---------------------------------------------------------------------------
# Packed storage: real b-bit mantissas + 5-bit exponents in uint32 words.
# ---------------------------------------------------------------------------

_PACK_CHUNK = 32             # values per packed chunk == lanes per uint32


def packed_words_per_axis(k: int, nbits: int) -> int:
    """uint32 words needed to pack a length-``k`` axis at ``nbits`` bits."""
    return -(-k // _PACK_CHUNK) * nbits


def pack_unsigned(u: jax.Array, nbits: int, *,
                  int32_shifts: bool = False) -> jax.Array:
    """Bit-planar pack of the last axis of ``u`` (values must be < 2**nbits).

    (..., K) uint32 -> (..., ceil(K/32) * nbits) uint32. See the module
    docstring for the wire layout.

    ``int32_shifts=True`` runs the identical shift/mask math on int32 words
    (uint32 in/out via bitcast) for Mosaic targets that lack u32 shifts.
    Two's-complement left shifts and wrapping adds preserve the exact bit
    pattern (each lane contributes one distinct bit — no carries), so the
    emitted words are bit-identical to the u32 path.
    """
    if not 1 <= nbits <= 16:
        raise ValueError(f"nbits must be in [1, 16], got {nbits}")
    u = jnp.asarray(u, jnp.uint32)
    k = u.shape[-1]
    pad = (-k) % _PACK_CHUNK
    if pad:
        u = jnp.pad(u, [(0, 0)] * (u.ndim - 1) + [(0, pad)])
    chunks = u.shape[-1] // _PACK_CHUNK
    wd = jnp.int32 if int32_shifts else jnp.uint32
    ug = u.reshape(*u.shape[:-1], chunks, _PACK_CHUNK)
    if int32_shifts:
        ug = jax.lax.bitcast_convert_type(ug, jnp.int32)
    lanes = jnp.arange(_PACK_CHUNK, dtype=wd)
    # plane p carries value bit (nbits-1-p): MSB plane first, so a word
    # prefix of the stream is the top-b-bits truncation (module docstring)
    planes = [jnp.sum(((ug >> wd(nbits - 1 - p)) & wd(1)) << lanes,
                      axis=-1, dtype=wd)
              for p in range(nbits)]
    words = jnp.stack(planes, axis=-2)            # (..., nbits, chunks)
    if int32_shifts:
        words = jax.lax.bitcast_convert_type(words, jnp.uint32)
    return words.reshape(*u.shape[:-1], nbits * chunks)


def unpack_unsigned(words: jax.Array, nbits: int, k: int, *,
                    int32_shifts: bool = False) -> jax.Array:
    """Inverse of :func:`pack_unsigned`: (..., nbits*ceil(k/32)) -> (..., k).

    ``nbits`` is the number of planes present in ``words`` — hand it the
    first ``b * chunks`` words of a wider stream with ``nbits=b`` and it
    decodes the top-b-bits truncation (the plane-prefix view).

    ``int32_shifts=True``: same math on bitcast int32 words (see
    :func:`pack_unsigned`); the ``& 1`` mask makes the arithmetic
    shift-right equivalent to the logical one bit-for-bit.
    """
    words = jnp.asarray(words, jnp.uint32)
    chunks = words.shape[-1] // nbits
    wd = jnp.int32 if int32_shifts else jnp.uint32
    w = words.reshape(*words.shape[:-1], nbits, chunks)
    if int32_shifts:
        w = jax.lax.bitcast_convert_type(w, jnp.int32)
    lanes = jnp.arange(_PACK_CHUNK, dtype=wd)
    u = jnp.zeros((*words.shape[:-1], chunks, _PACK_CHUNK), wd)
    for p in range(nbits):
        bits_p = (w[..., p, :][..., None] >> lanes) & wd(1)
        u = u | (bits_p << wd(nbits - 1 - p))
    u = u.reshape(*words.shape[:-1], chunks * _PACK_CHUNK)
    # unpacked fields are < 2**16, so the int32 path is nonneg: plain astype
    return u.astype(jnp.uint32)[..., :k]


def pack_mantissas(m: jax.Array, bits: int, *,
                   int32_shifts: bool = False) -> jax.Array:
    """int8 mantissas (..., K) -> offset-binary packed uint32 words.

    Offset is ``mantissa_offset(bits)`` = 2^(bits-1), the power-of-two
    choice that makes plane-prefix truncation exact (module docstring).
    """
    u = (m.astype(jnp.int32) + mantissa_offset(bits)).astype(jnp.uint32)
    return pack_unsigned(u, bits, int32_shifts=int32_shifts)


def unpack_mantissas(words: jax.Array, bits: int, k: int, *,
                     int32_shifts: bool = False) -> jax.Array:
    """Packed words -> int8 mantissas (..., k).

    ``bits`` is the plane count of ``words``: decoding the first
    ``b * chunks`` words of a wider stream with ``bits=b`` yields the
    floor-truncated mantissas ``m >> (stored - b)`` — range
    ``[-2^(b-1), 2^(b-1)-1]`` (asymmetric; see :func:`mantissa_abs_max`).
    """
    u = unpack_unsigned(words, bits, k, int32_shifts=int32_shifts)
    return (u.astype(jnp.int32) - mantissa_offset(bits)).astype(jnp.int8)


def pack_exponents(e: jax.Array) -> jax.Array:
    """int8 unbiased exponents (any shape) -> flat packed uint32 words."""
    u = (e.astype(jnp.int32) + EXP_BIAS).astype(jnp.uint32).reshape(-1)
    return pack_unsigned(u, EXP_BITS)


def unpack_exponents(words: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
    """Flat packed words -> int8 unbiased exponents of ``shape``."""
    n = int(np.prod(shape)) if shape else 1
    u = unpack_unsigned(words, EXP_BITS, n)
    return (u.astype(jnp.int32) - EXP_BIAS).astype(jnp.int8).reshape(shape)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class PackedGSETensor:
    """A tensor in *really packed* GSE storage.

    Attributes:
      mantissa_words: uint32, shape = source shape with last dim replaced by
        ``active_bits * ceil(K/32)`` (plane-major bit planes, see module
        docstring) — a plane-prefix *view* carries only its active planes.
      exponent_words: uint32 1-D, ``ceil(n_groups/32) * 5`` words. Always
        the full-width exponents: truncation shares them (that is the whole
        point — the prefix reads against the *same* shared exponent).
      stored_bits: mantissa width the stream was packed at (static).
      group_size: values per shared exponent (static).
      shape: logical (unpacked) mantissa shape (static).
      active_bits: planes this handle reads (static); ``None`` at
        construction means "all of them". ``active_bits < stored_bits``
        marks a plane-prefix view: decoded mantissas are the stored ones
        floor-truncated by ``exp_shift = stored_bits - active_bits`` and
        consumers add ``exp_shift`` to the shared exponents.
    """
    mantissa_words: jax.Array
    exponent_words: jax.Array
    stored_bits: int
    group_size: int
    shape: Tuple[int, ...]
    active_bits: int | None = None

    def __post_init__(self):
        if self.active_bits is None:
            object.__setattr__(self, "active_bits", self.stored_bits)
        if not 2 <= self.active_bits <= self.stored_bits:
            raise ValueError(
                f"active_bits {self.active_bits} outside "
                f"[2, stored_bits={self.stored_bits}]")

    @property
    def bits(self) -> int:
        """Width this handle *reads* at (== ``active_bits``): qmax,
        bytes-moved, and kernel plane counts all follow the active width."""
        return self.active_bits

    @property
    def exp_shift(self) -> int:
        """Exponent compensation of the plane-prefix view: 0 for a
        full-width handle, ``stored_bits - active_bits`` for a view."""
        return self.stored_bits - self.active_bits

    @property
    def exponent_shape(self) -> Tuple[int, ...]:
        return (*self.shape[:-1], self.shape[-1] // self.group_size)

    @property
    def nbytes(self) -> int:
        """Live packed bytes — the quantity the paper's Tab. 1 claims.
        A plane-prefix view counts only its active planes (the bytes a
        consumer actually moves)."""
        return int(self.mantissa_words.size) * 4 \
            + int(self.exponent_words.size) * 4

    def with_bits(self, b: int) -> "PackedGSETensor":
        """Zero-copy plane-prefix view at ``b <= active_bits`` bits.

        A pure word slice: the plane-major layout puts the ``b`` most
        significant planes of every chunk in the first ``b * chunks`` words
        of the packed axis, so the view is ``mantissa_words[..., :b*chunks]``
        sharing the exponent words — no unpack, no re-quantization, no new
        buffer beyond the slice. Decoding it yields the floor-truncation
        ``m >> (stored_bits - b)`` against exponents ``e + (stored_bits-b)``
        (see :func:`gse_unpack`). Views compose: ``.with_bits(6).with_bits(4)
        == .with_bits(4)``. For the re-quantization tier (round-to-nearest
        at b bits, fresh exponents) use :meth:`requantize` — docs
        gse-format.md §7 tabulates the accuracy gap.
        """
        if not 2 <= b <= self.active_bits:
            raise ValueError(f"with_bits({b}): need 2 <= b <= active_bits "
                             f"({self.active_bits})")
        if b == self.active_bits:
            return self
        words = plane_prefix_words(self.mantissa_words, self.active_bits, b)
        return PackedGSETensor(words, self.exponent_words, self.stored_bits,
                               self.group_size, self.shape, b)

    def requantize(self, b: int) -> "PackedGSETensor":
        """The *other* tier: fresh round-to-nearest ``b``-bit quantization
        (new exponents, materializes the values). Strictly more accurate
        than :meth:`with_bits` (floor vs nearest, exponents re-fit) at the
        cost of a full dequant/requant pass — use it offline, use
        ``with_bits`` on the serving read path."""
        return gse_pack(gse_quantize(self.dequantize(), b, self.group_size))

    def tree_flatten_with_keys(self):
        return (
            ((jax.tree_util.GetAttrKey("mantissa_words"), self.mantissa_words),
             (jax.tree_util.GetAttrKey("exponent_words"), self.exponent_words)),
            (self.stored_bits, self.active_bits, self.group_size,
             tuple(self.shape)),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[2], aux[3], aux[1])

    def unpack(self) -> GSETensor:
        return gse_unpack(self)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return gse_dequantize(self.unpack(), dtype)


@jax.jit
def gse_pack(t: GSETensor) -> PackedGSETensor:
    """GSETensor (int8 working form) -> PackedGSETensor (uint32 storage).

    Layout selection is a pure function of the logical shape (so unpack
    needs no extra metadata): when the last axis is a multiple of 32 the
    mantissas pack **per row** — leading axes preserved, directly tileable
    by the Pallas kernels; otherwise the fully flattened value stream packs
    into a 1-D word array (at most 31 values of padding total, so small
    trailing axes — e.g. KV-cache head_dims — pay no per-row chunk waste).

    Bit-exact: ``gse_unpack(gse_pack(t))`` reproduces mantissa and exponent
    arrays exactly for any bits in [2, 8].
    """
    if t.mantissa.shape[-1] % _PACK_CHUNK == 0:
        mw = pack_mantissas(t.mantissa, t.bits)
    else:
        mw = pack_mantissas(t.mantissa.reshape(-1), t.bits)
    ew = pack_exponents(t.exponent)
    return PackedGSETensor(mw, ew, t.bits, t.group_size,
                           tuple(t.mantissa.shape))


@jax.jit
def gse_unpack(p: PackedGSETensor) -> GSETensor:
    """PackedGSETensor -> GSETensor, inverse of :func:`gse_pack`.

    For a plane-prefix view (``active_bits < stored_bits``) this decodes
    the truncated mantissas ``m >> exp_shift`` and returns the shared
    exponents with ``exp_shift`` already folded in (``e + exp_shift``), so
    ``.dequantize()`` of a view is directly the truncated values. Folded
    exponents may exceed ``EXP_MAX`` (never re-pack them through the 5-bit
    field) and truncated mantissas may reach ``-2^(b-1)``.
    """
    if p.shape[-1] % _PACK_CHUNK == 0:
        m = unpack_mantissas(p.mantissa_words, p.active_bits, p.shape[-1])
    else:
        n = int(np.prod(p.shape))
        m = unpack_mantissas(p.mantissa_words, p.active_bits, n)
    m = m.reshape(p.shape)
    e = unpack_exponents(p.exponent_words, p.exponent_shape)
    if p.exp_shift:
        e = (e.astype(jnp.int32) + p.exp_shift).astype(jnp.int8)
    return GSETensor(m, e, p.active_bits, p.group_size)


def _group_reshape(x: jax.Array, group_size: int) -> jax.Array:
    """(..., K) -> (..., K // g, g). K must be divisible by g."""
    k = x.shape[-1]
    if k % group_size != 0:
        raise ValueError(
            f"last dim {k} not divisible by group_size {group_size}")
    return x.reshape(*x.shape[:-1], k // group_size, group_size)


def compute_group_exponent(x: jax.Array, bits: int, group_size: int) -> jax.Array:
    """Per-group shared exponent e_g = ceil(log2(amax / qmax)), clamped to 5 bits.

    Returns int8 of shape (..., K // group_size).
    """
    qmax = qmax_for_bits(bits)
    xg = _group_reshape(as_f32_exact(jnp.asarray(x)), group_size)
    amax = jnp.max(jnp.abs(xg), axis=-1)
    # exact ceil(log2(amax/qmax)) — see ceil_log2; zero groups -> EXP_MIN.
    safe = jnp.where(amax > 0, amax, 1.0)
    e = ceil_log2(safe / qmax)
    e = jnp.where(amax > 0, e, EXP_MIN)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    return e.astype(jnp.int8)


def _round_to_nearest_even(x: jax.Array) -> jax.Array:
    return jnp.round(x)  # jnp.round is round-half-to-even, matching RTN HW.


def _stochastic_round(x: jax.Array, key: jax.Array) -> jax.Array:
    floor = jnp.floor(x)
    frac = x - floor
    return floor + (jax.random.uniform(key, x.shape) < frac).astype(x.dtype)


@partial(jax.jit, static_argnames=("bits", "group_size", "stochastic"))
def gse_quantize(
    x: jax.Array,
    bits: int = 6,
    group_size: int = DEFAULT_GROUP,
    *,
    stochastic: bool = False,
    key: jax.Array | None = None,
) -> GSETensor:
    """Quantize ``x`` to GSE along its last axis.

    Round-to-nearest by default (the paper's choice); stochastic rounding is
    exposed for the gradient-compression path.
    """
    qmax = qmax_for_bits(bits)
    xf = as_f32_exact(jnp.asarray(x))
    e = compute_group_exponent(xf, bits, group_size)
    xg = _group_reshape(xf, group_size)
    scale = exp2_int(e)[..., None]
    y = xg / scale
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        m = _stochastic_round(y, key)
    else:
        m = _round_to_nearest_even(y)
    m = jnp.clip(m, -qmax, qmax).astype(jnp.int8)
    m = m.reshape(x.shape)
    return GSETensor(m, e, bits, group_size)


@partial(jax.jit, static_argnames=("dtype",))
def gse_dequantize(t: GSETensor, dtype=jnp.float32) -> jax.Array:
    mg = _group_reshape(t.mantissa.astype(jnp.float32), t.group_size)
    scale = exp2_int(t.exponent)[..., None]
    return (mg * scale).reshape(t.mantissa.shape).astype(dtype)


def gse_dequantize_in(t, dtype) -> jax.Array:
    """Dequantize with the *exact* op sequence of :func:`gse_fake_quant`'s
    final multiply: mantissas cast to ``dtype``, the power-of-two scale
    built exactly (``exp2_int``) in fp32 then cast to ``dtype``, and the
    fat multiply performed in ``dtype``.

    This is what makes the packed-residual QCD path bit-identical to the
    fake-quant simulation: ``gse_dequantize_in(gse_quantize(x, b, g), x.dtype)
    == gse_fake_quant(x, b, g)`` for every dtype whose mantissa holds qmax
    exactly (bf16 and wider for b <= 8) — both sides use the exact-integer
    exponent math (``ceil_log2``/``exp2_int``) and multiply in the same
    dtype, so neither XLA's transcendental approximations nor fusion
    context can break the parity.

    Accepts a :class:`GSETensor` or a :class:`PackedGSETensor`.
    """
    if isinstance(t, PackedGSETensor):
        t = gse_unpack(t)
    mg = _group_reshape(t.mantissa.astype(dtype), t.group_size)
    scale = exp2_int(t.exponent).astype(dtype)
    return (mg * scale[..., None]).reshape(t.mantissa.shape)


@partial(jax.jit, static_argnames=("bits", "group_size"))
def gse_fake_quant(x: jax.Array, bits: int = 6,
                   group_size: int = DEFAULT_GROUP) -> jax.Array:
    """Quantize-dequantize in one shot (same dtype in/out).

    This is the simulation primitive used inside QCD matmuls. Every step is
    value-exact: the fp32 working view is built from the input's bit
    pattern (``as_f32_exact`` — an ordinary convert can be elided under
    XLA's excess-precision folding, letting the quantizer see unrounded GEMM
    outputs in a fusion-dependent way), the shared exponent and scales use
    the exact-integer helpers (``ceil_log2``/``exp2_int``), the
    power-of-two scaling and the final ``m * 2^e`` products are exact in
    fp32 and in bf16 alike, so the trailing cast back to the input dtype is
    lossless and the result is a pure function of the stored input values —
    in any program, under any fusion. (This replaces the §Perf iter 5
    stay-in-bf16 posture, which the packed-residual parity contract of
    repro.core.qcd broke on: bf16 fat math is only bit-stable if the
    compiler never keeps excess precision, which it does not guarantee.)
    """
    dtype = x.dtype
    qmax = qmax_for_bits(bits)
    xg = _group_reshape(as_f32_exact(x), group_size)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    safe = jnp.where(amax > 0, amax, 1.0)
    e = jnp.clip(ceil_log2(safe / qmax), EXP_MIN, EXP_MAX)
    inv = exp2_int(-e)
    # zero groups: scale = 0 folds the zeroing into the dequant multiply —
    # one fat elementwise pass fewer than a separate where (§Perf iter 8)
    scale = jnp.where(amax > 0, exp2_int(e), 0.0)
    m = jnp.clip(jnp.round(xg * inv), -qmax, qmax)
    return (m * scale).reshape(x.shape).astype(dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def gse_fake_quant_ste(x: jax.Array, bits: int = 6,
                       group_size: int = DEFAULT_GROUP) -> jax.Array:
    """Straight-through-estimator fake quant: forward = GSE round-trip,
    backward = identity. For quantizing activation-activation GEMO operands
    (e.g. SSD intra-chunk matmuls) where the plain ``round`` VJP would
    zero the gradient."""
    return gse_fake_quant(x, bits, group_size)


def _ste_fwd(x, bits, group_size):
    return gse_fake_quant(x, bits, group_size), None


def _ste_bwd(bits, group_size, _, g):
    return (g,)


gse_fake_quant_ste.defvjp(_ste_fwd, _ste_bwd)


def gse_matmul_reference(a: GSETensor, b: GSETensor) -> jax.Array:
    """Reference GSE×GSE matmul: (M, K) @ (N, K)^T -> (M, N) in fp32.

    Both operands are grouped along K. Computed exactly as the paper's
    eq. for the dot product: per-group int MAC then scale by 2^(eA+eB).

    Accumulation contract: the per-group int32 MAC is exact, each scaled
    group product is exact in fp32 (power-of-two scale), and the cross-group
    fp32 accumulation happens **sequentially in ascending group order**.
    The Pallas kernels implement the same ordered accumulation, which is
    what makes kernel-vs-reference parity bit-exact for arbitrary inputs
    (an unordered ``sum`` would differ by rounding at 8-bit magnitudes).
    """
    if a.group_size != b.group_size:
        raise ValueError("group_size mismatch")
    g = a.group_size
    m, k = a.mantissa.shape
    n, k2 = b.mantissa.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {k} vs {k2}")
    ag = a.mantissa.reshape(m, k // g, g).astype(jnp.int32)
    bg = b.mantissa.reshape(n, k // g, g).astype(jnp.int32)
    # per-group integer dot: (M, N, K//g)
    prod = jnp.einsum("mgk,ngk->mng", ag, bg)
    scale = exp2_int(a.exponent[:, None, :].astype(jnp.int32)
                     + b.exponent[None, :, :].astype(jnp.int32))
    terms = prod.astype(jnp.float32) * scale
    acc = jnp.zeros((m, n), jnp.float32)
    for gi in range(k // g):          # ordered fp32 accumulation (contract)
        acc = acc + terms[:, :, gi]
    return acc


def gse_bits_per_value(bits: int, group_size: int = DEFAULT_GROUP) -> float:
    """Effective storage bits/value including amortized shared exponent."""
    return bits + EXP_BITS / group_size


def quantization_error(x: jax.Array, bits: int,
                       group_size: int = DEFAULT_GROUP) -> dict:
    """MSE / SQNR metrics of GSE round-trip on ``x`` (diagnostics/benchmarks)."""
    xf = jnp.asarray(x, jnp.float32)
    xq = gse_fake_quant(xf, bits, group_size)
    err = xf - xq
    mse = jnp.mean(err ** 2)
    sig = jnp.mean(xf ** 2)
    sqnr_db = 10.0 * jnp.log10(jnp.where(mse > 0, sig / mse, jnp.inf))
    return {"mse": mse, "sqnr_db": sqnr_db}
