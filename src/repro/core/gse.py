"""Group-Shared Exponents Integer (GSE) format.

The paper's core numeric format: a group of ``group_size`` contiguous values
along the matmul contraction axis shares one 5-bit exponent; each value keeps
a signed integer mantissa of ``bits`` total bits (symmetric range, no implicit
leading one):

    x_i ~= m_i * 2^(e_g)          m_i in [-(2^(b-1)-1), 2^(b-1)-1]

Storage is ``N*b + E`` bits per group versus ``N*(E+M+1)`` for FP — the
shared exponent amortizes to ~0.16 bits/value at N=32.

This module is the *value-space* reference implementation used throughout the
framework (models, QCD matmul, gradient compression). The Pallas kernels in
``repro.kernels`` implement the same math with explicit VMEM tiling and are
validated against this module.

Conventions
-----------
* Quantization always happens along the **last** axis of the tensor handed in
  (callers transpose so the contraction axis is last).
* The exponent is stored as int8 holding the *unbiased* exponent value in
  [-EXP_BIAS, EXP_BIAS - 1] (5-bit field, bias 16).
* Mantissas are stored in int8 regardless of ``bits`` (5..8); values are
  clamped to the b-bit symmetric range. True b-bit packing is accounted for
  analytically by :func:`gse_bits_per_value` (used by the memory model).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

EXP_BITS = 5                 # fixed by the paper (Sec. 2.2)
EXP_BIAS = 16                # unbiased exponent range [-16, 15]
EXP_MIN = -EXP_BIAS
EXP_MAX = EXP_BIAS - 1
DEFAULT_GROUP = 32           # paper's default group size (Tab. 6)


def qmax_for_bits(bits: int) -> int:
    """Largest mantissa magnitude for a b-bit symmetric signed integer."""
    if not 2 <= bits <= 8:
        raise ValueError(f"GSE bits must be in [2, 8], got {bits}")
    return (1 << (bits - 1)) - 1


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class GSETensor:
    """A tensor held in GSE format.

    Attributes:
      mantissa: int8, same shape as the source tensor.
      exponent: int8, shape = source shape with last dim ``// group_size``.
      bits: mantissa bit-width (metadata).
      group_size: values per shared exponent (metadata).
    """
    mantissa: jax.Array
    exponent: jax.Array
    bits: int
    group_size: int

    @property
    def shape(self):
        return self.mantissa.shape

    @property
    def dtype(self):
        return jnp.int8

    def tree_flatten(self):
        return (self.mantissa, self.exponent), (self.bits, self.group_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return gse_dequantize(self, dtype)

    def nbytes_packed(self) -> int:
        """True packed size in bytes (b-bit mantissas + 5-bit exponents)."""
        n = int(np.prod(self.mantissa.shape))
        g = int(np.prod(self.exponent.shape))
        return (n * self.bits + g * EXP_BITS + 7) // 8


def _group_reshape(x: jax.Array, group_size: int) -> jax.Array:
    """(..., K) -> (..., K // g, g). K must be divisible by g."""
    k = x.shape[-1]
    if k % group_size != 0:
        raise ValueError(
            f"last dim {k} not divisible by group_size {group_size}")
    return x.reshape(*x.shape[:-1], k // group_size, group_size)


def compute_group_exponent(x: jax.Array, bits: int, group_size: int) -> jax.Array:
    """Per-group shared exponent e_g = ceil(log2(amax / qmax)), clamped to 5 bits.

    Returns int8 of shape (..., K // group_size).
    """
    qmax = qmax_for_bits(bits)
    xg = _group_reshape(jnp.asarray(x, jnp.float32), group_size)
    amax = jnp.max(jnp.abs(xg), axis=-1)
    # ceil(log2(amax/qmax)); zero groups pinned to EXP_MIN.
    safe = jnp.where(amax > 0, amax, 1.0)
    e = jnp.ceil(jnp.log2(safe / qmax))
    e = jnp.where(amax > 0, e, float(EXP_MIN))
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    return e.astype(jnp.int8)


def _round_to_nearest_even(x: jax.Array) -> jax.Array:
    return jnp.round(x)  # jnp.round is round-half-to-even, matching RTN HW.


def _stochastic_round(x: jax.Array, key: jax.Array) -> jax.Array:
    floor = jnp.floor(x)
    frac = x - floor
    return floor + (jax.random.uniform(key, x.shape) < frac).astype(x.dtype)


@partial(jax.jit, static_argnames=("bits", "group_size", "stochastic"))
def gse_quantize(
    x: jax.Array,
    bits: int = 6,
    group_size: int = DEFAULT_GROUP,
    *,
    stochastic: bool = False,
    key: jax.Array | None = None,
) -> GSETensor:
    """Quantize ``x`` to GSE along its last axis.

    Round-to-nearest by default (the paper's choice); stochastic rounding is
    exposed for the gradient-compression path.
    """
    qmax = qmax_for_bits(bits)
    xf = jnp.asarray(x, jnp.float32)
    e = compute_group_exponent(xf, bits, group_size)
    xg = _group_reshape(xf, group_size)
    scale = jnp.exp2(e.astype(jnp.float32))[..., None]
    y = xg / scale
    if stochastic:
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        m = _stochastic_round(y, key)
    else:
        m = _round_to_nearest_even(y)
    m = jnp.clip(m, -qmax, qmax).astype(jnp.int8)
    m = m.reshape(x.shape)
    return GSETensor(m, e, bits, group_size)


@partial(jax.jit, static_argnames=("dtype",))
def gse_dequantize(t: GSETensor, dtype=jnp.float32) -> jax.Array:
    mg = _group_reshape(t.mantissa.astype(jnp.float32), t.group_size)
    scale = jnp.exp2(t.exponent.astype(jnp.float32))[..., None]
    return (mg * scale).reshape(t.mantissa.shape).astype(dtype)


@partial(jax.jit, static_argnames=("bits", "group_size"))
def gse_fake_quant(x: jax.Array, bits: int = 6,
                   group_size: int = DEFAULT_GROUP) -> jax.Array:
    """Quantize-dequantize in one shot (same dtype in/out).

    This is the simulation primitive used inside QCD matmuls. The fat
    tensor math stays in the INPUT dtype (bf16 on the training path —
    §Perf iteration 5): dividing by a power-of-two scale is exact in any
    binary float, ``round`` of values <= qmax <= 127 is exact in bf16, and
    only the per-group amax/exponent stats (tiny) run in fp32.
    """
    dtype = x.dtype
    qmax = qmax_for_bits(bits)
    xg = _group_reshape(x, group_size)
    amax = jnp.max(jnp.abs(xg.astype(jnp.float32)), axis=-1, keepdims=True)
    safe = jnp.where(amax > 0, amax, 1.0)
    e = jnp.clip(jnp.ceil(jnp.log2(safe / qmax)), EXP_MIN, EXP_MAX)
    inv = jnp.exp2(-e).astype(dtype)
    # zero groups: scale = 0 folds the zeroing into the dequant multiply —
    # one fat elementwise pass fewer than a separate where (§Perf iter 8)
    scale = jnp.where(amax > 0, jnp.exp2(e), 0.0).astype(dtype)
    m = jnp.clip(jnp.round(xg * inv), -qmax, qmax)
    return (m * scale).reshape(x.shape).astype(dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def gse_fake_quant_ste(x: jax.Array, bits: int = 6,
                       group_size: int = DEFAULT_GROUP) -> jax.Array:
    """Straight-through-estimator fake quant: forward = GSE round-trip,
    backward = identity. For quantizing activation-activation GEMO operands
    (e.g. SSD intra-chunk matmuls) where the plain ``round`` VJP would
    zero the gradient."""
    return gse_fake_quant(x, bits, group_size)


def _ste_fwd(x, bits, group_size):
    return gse_fake_quant(x, bits, group_size), None


def _ste_bwd(bits, group_size, _, g):
    return (g,)


gse_fake_quant_ste.defvjp(_ste_fwd, _ste_bwd)


def gse_matmul_reference(a: GSETensor, b: GSETensor) -> jax.Array:
    """Reference GSE×GSE matmul: (M, K) @ (N, K)^T -> (M, N) in fp32.

    Both operands are grouped along K. Computed exactly as the paper's
    eq. for the dot product: per-group int MAC then scale by 2^(eA+eB).
    """
    if a.group_size != b.group_size:
        raise ValueError("group_size mismatch")
    g = a.group_size
    m, k = a.mantissa.shape
    n, k2 = b.mantissa.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {k} vs {k2}")
    ag = a.mantissa.reshape(m, k // g, g).astype(jnp.int32)
    bg = b.mantissa.reshape(n, k // g, g).astype(jnp.int32)
    # per-group integer dot: (M, N, K//g)
    prod = jnp.einsum("mgk,ngk->mng", ag, bg)
    scale = jnp.exp2(
        (a.exponent[:, None, :].astype(jnp.float32)
         + b.exponent[None, :, :].astype(jnp.float32)))
    return jnp.sum(prod.astype(jnp.float32) * scale, axis=-1)


def gse_bits_per_value(bits: int, group_size: int = DEFAULT_GROUP) -> float:
    """Effective storage bits/value including amortized shared exponent."""
    return bits + EXP_BITS / group_size


def quantization_error(x: jax.Array, bits: int,
                       group_size: int = DEFAULT_GROUP) -> dict:
    """MSE / SQNR metrics of GSE round-trip on ``x`` (diagnostics/benchmarks)."""
    xf = jnp.asarray(x, jnp.float32)
    xq = gse_fake_quant(xf, bits, group_size)
    err = xf - xq
    mse = jnp.mean(err ** 2)
    sig = jnp.mean(xf ** 2)
    sqnr_db = 10.0 * jnp.log10(jnp.where(mse > 0, sig / mse, jnp.inf))
    return {"mse": mse, "sqnr_db": sqnr_db}
