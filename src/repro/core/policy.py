"""Quantization policy — the "W-A-G" bit configuration of the paper.

Paper notation "4-6-6 / 6-6-6" means: base branch W=NF4, activations=6,
gradients=6; low-rank branch adapters/acts/grads = 6. ``QuantPolicy``
captures one branch-pair configuration plus format knobs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.gse import DEFAULT_GROUP, gse_bits_per_value


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Bit-widths for the fully-quantized fine-tuning pipeline.

    ``None`` for any field disables quantization of that tensor class
    (e.g. the QLoRA BF16 baseline is ``QuantPolicy.qlora_bf16()``).
    """
    # base (frozen) branch
    base_w_nf4: bool = True           # store base W as NF4 (QLoRA substrate)
    a_bits: Optional[int] = 6         # activation bits (GSE)
    w_bits: Optional[int] = 6         # GSE bits for the (dequantized) base W
    g_bits: Optional[int] = 6         # gradient bits (GSE)
    # low-rank branch
    adapter_bits: Optional[int] = 6   # GSE bits for A/B and their acts/grads
    # format
    group_size: int = DEFAULT_GROUP
    fmt: str = "gse"                  # "gse" | "fp8_e4m3" | "fp8_e5m2" | "none"
    stochastic_grad: bool = False
    # QCD backward residuals: store the tensors saved for the backward GEMMs
    # as packed GSE word streams (b-bit bit-planar mantissas + packed 5-bit
    # shared exponents) instead of fake-quantized bf16 — the realized
    # activation-memory claim. ``residual_bits=None`` stores residuals at
    # the operand bit-width (backward is then bit-identical to the
    # fake-quant path); setting it lower trades gradient fidelity for
    # residual bytes (QFT-style low-bit activation checkpointing).
    residuals_packed: bool = False
    residual_bits: Optional[int] = None
    # Integer MACs in the packed backward matmuls (bounded tier): realign
    # mantissas to a tile-shared exponent in VMEM and accumulate in int32
    # instead of dequantizing tiles to fp32 — the paper's integer-compute
    # claim on the dX/dW GEMMs. NOT bit-exact (realignment drops low bits;
    # worst-case bound in docs/architecture.md), hence default off; the
    # fp32 kernels remain the oracle. REPRO_INT_MAC=1/0 overrides.
    int_mac: bool = False
    # rank of LoRA adapters (co-optimized with bits; Sec. 2.4)
    rank: int = 64
    lora_alpha: float = 16.0

    # ---- paper presets -------------------------------------------------
    @classmethod
    def gsq(cls, bits: int, rank: int = 64, group_size: int = DEFAULT_GROUP,
            residuals_packed: bool = False):
        """GSQ-Tuning ' 4-b-b / b-b-b ' row of Tab. 1/8."""
        return cls(a_bits=bits, w_bits=bits, g_bits=bits, adapter_bits=bits,
                   rank=rank, group_size=group_size,
                   residuals_packed=residuals_packed)

    @classmethod
    def qlora_bf16(cls, rank: int = 64):
        """QLoRA baseline: NF4 base, everything else BF16 (4-16-16)."""
        return cls(a_bits=None, w_bits=None, g_bits=None, adapter_bits=None,
                   rank=rank, fmt="none")

    @classmethod
    def fp8(cls, fmt: str = "e4m3", rank: int = 64):
        """FP8 FQT baseline of Tab. 2 (4-8-8 with FP8 data format)."""
        return cls(a_bits=8, w_bits=8, g_bits=8, adapter_bits=8,
                   rank=rank, fmt=f"fp8_{fmt}")

    @classmethod
    def full_bf16(cls):
        """16-16-16 full fine-tuning baseline (no adapters, no quant)."""
        return cls(base_w_nf4=False, a_bits=None, w_bits=None, g_bits=None,
                   adapter_bits=None, rank=0, fmt="none")

    # ---- derived -------------------------------------------------------
    @property
    def quantized(self) -> bool:
        return self.fmt != "none"

    def label(self) -> str:
        if self.fmt == "none":
            base = "4-16-16" if self.base_w_nf4 else "16-16-16"
            lr = "16-16-16" if self.rank else "w/o"
            return f"{base} / {lr}"
        b = self.a_bits
        return f"4-{b}-{b} / {b}-{b}-{b} ({self.fmt}, g{self.group_size}, r{self.rank})"

    def act_bits_per_value(self) -> float:
        if self.a_bits is None:
            return 16.0
        if self.fmt.startswith("fp8"):
            return 8.0
        return gse_bits_per_value(self.a_bits, self.group_size)
