"""Core GSQ-Tuning primitives: GSE format, NF4, FP8 baseline, QCD matmul,
quantization policy, and the GSQ LoRA linear layer."""
from repro.core.gse import (GSETensor, PackedGSETensor, gse_quantize,
                            gse_dequantize, gse_fake_quant, gse_pack,
                            gse_unpack, gse_matmul_reference,
                            gse_bits_per_value, quantization_error,
                            DEFAULT_GROUP, EXP_BITS, EXP_BIAS)
from repro.core.nf4 import NF4Tensor, nf4_quantize, nf4_dequantize, nf4_fake_quant
from repro.core.fp8 import fp8_fake_quant, fp8_quantization_error
from repro.core.qcd import quantized_matmul, effective_group_size
from repro.core.policy import QuantPolicy
from repro.core.lora import (init_gsq_linear, apply_gsq_linear, merge_lora,
                             gsq_param_count)

__all__ = [
    "GSETensor", "PackedGSETensor", "gse_quantize", "gse_dequantize",
    "gse_fake_quant", "gse_pack", "gse_unpack",
    "gse_matmul_reference", "gse_bits_per_value", "quantization_error",
    "DEFAULT_GROUP", "EXP_BITS", "EXP_BIAS",
    "NF4Tensor", "nf4_quantize", "nf4_dequantize", "nf4_fake_quant",
    "fp8_fake_quant", "fp8_quantization_error",
    "quantized_matmul", "effective_group_size", "QuantPolicy",
    "init_gsq_linear", "apply_gsq_linear", "merge_lora", "gsq_param_count",
]
