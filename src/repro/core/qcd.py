"""Quantize-Compute-Dequantize (QCD) matrix multiplication with fully
quantized backward pass — the paper's Sec. 2.3.

Every GEMM in the fine-tuning graph (forward *and* backward) runs on
GSE-quantized operands:

    fwd:  Y  = Q^-1( Q(X) @ Q(W) )
    bwd:  dX = Q^-1( Q(dY) @ Q(W)^T )
          dW = Q^-1( Q(X)^T @ Q(dY) )

Each operand is quantized **along the contraction axis of that particular
GEMM** (so W is grouped along K for the forward, along N for dX — this is the
standard FQT convention, cf. Jetfire), with the group-shared 5-bit exponent
of :mod:`repro.core.gse`.

Simulation note: we compute with fake-quantized fp32/bf16 operands and let
XLA run the GEMM. On TPU the same math lowers to the Pallas int8 MXU kernel
(``repro.kernels.gse_matmul``); fp32 accumulation differs from exact int32
accumulation by ~1e-7 relative — far below quantization noise. Tests compare
both paths.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.gse import gse_fake_quant, DEFAULT_GROUP


def effective_group_size(k: int, group_size: int) -> int:
    """Largest divisor of ``k`` that is <= group_size.

    LoRA ranks (16, 32, ...) can be smaller than the group size; grouping then
    degrades gracefully to per-``k`` granularity (more exponents, never less
    precision).
    """
    g = min(group_size, k)
    while k % g != 0:
        g -= 1
    return g


def _fq(x: jax.Array, bits: Optional[int], group_size: int) -> jax.Array:
    """Fake-quantize along the last (contraction) axis; bits=None = passthrough."""
    if bits is None:
        return x
    g = effective_group_size(x.shape[-1], group_size)
    return gse_fake_quant(x, bits, g)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def quantized_matmul(
    x: jax.Array,
    w: jax.Array,
    a_bits: Optional[int] = 6,
    w_bits: Optional[int] = 6,
    g_bits: Optional[int] = 6,
    group_size: int = DEFAULT_GROUP,
) -> jax.Array:
    """``x @ w`` with GSE-quantized operands and gradients.

    Args:
      x: (..., K) activations — quantized to ``a_bits`` along K.
      w: (K, N) weights — quantized to ``w_bits`` along K (fwd) / N (bwd dX).
      g_bits: gradient bit-width for dY in the backward GEMMs.
      group_size: GSE group size (contrab-axis groups).

    Any of the bit-widths may be None to keep that operand in full precision
    (used for ablations and the QLoRA BF16 baseline).
    """
    y, _ = _qmm_fwd(x, w, a_bits, w_bits, g_bits, group_size)
    return y


def _qmm_fwd(x, w, a_bits, w_bits, g_bits, group_size):
    xq = _fq(x, a_bits, group_size)
    # w: (K, N); contraction axis K is first -> quantize along axis 0.
    # Named so the remat policy can SAVE the quantized weight instead of
    # re-running NF4-dequant + GSE-quant in the backward pass (§Perf iter 6).
    from jax.ad_checkpoint import checkpoint_name
    wq = checkpoint_name(_fq(w.T, w_bits, group_size).T, "qcd_wq")
    # bf16 GEMM output: the MXU accumulates fp32 internally regardless; a
    # bf16 result halves the all-reduce payload of row-parallel partials
    # (§Perf iteration 1 — was preferred_element_type=f32).
    import os as _os
    if _os.environ.get("REPRO_QCD_F32_OUT"):
        y = jnp.matmul(xq, wq, preferred_element_type=jnp.float32
                       ).astype(x.dtype)
    else:
        y = jnp.matmul(xq, wq)
    # Residuals: keep the *quantized* tensors — backward consumes Q(X), Q(W)
    # exactly as stored (paper's backward eqs reuse the forward Q(·);
    # re-quantizing per-use turned out to cost full-weight/activation
    # all-gathers in SPMD — §Perf iteration 2/3).
    return y, (xq, wq)


def _qmm_bwd(a_bits, w_bits, g_bits, group_size, res, dy):
    xq, wq = res
    dyq = _fq(dy, g_bits, group_size)                        # grouped along N
    # dX = Q(dY) @ Q(W)^T : contraction over N, reusing the forward-grouped
    # Q(W) per the paper's dL/dX equation (no per-use re-grouping).
    import os as _os
    if _os.environ.get("REPRO_QCD_F32_OUT"):
        dx = jnp.matmul(dyq, wq.T, preferred_element_type=jnp.float32
                        ).astype(dy.dtype)
    else:
        dx = jnp.matmul(dyq, wq.T)
    # dW = Q(X)^T @ Q(dY) : contraction over tokens, reusing forward Q(X)
    # and the N-grouped Q(dY). Grouping does not align with the contraction
    # axis here, so this GEMM runs as a bf16 MAC on hardware (dW is the
    # cheapest of the three GEMMs; DESIGN §4 note).
    x2 = xq.reshape(-1, xq.shape[-1])                         # (B, K)
    dy2 = dyq.reshape(-1, dyq.shape[-1])                      # (B, N)
    dw = jnp.matmul(x2.T, dy2, preferred_element_type=jnp.float32
                    ).astype(dy.dtype)
    return dx, dw


def _qmm_bwd_wrap(a_bits, w_bits, g_bits, group_size, res, dy):
    dx, dw = _qmm_bwd(a_bits, w_bits, g_bits, group_size, res, dy)
    return (dx, dw)


quantized_matmul.defvjp(_qmm_fwd, _qmm_bwd_wrap)


def quantized_einsum_btd_dn(x, w, a_bits, w_bits, g_bits, group_size=DEFAULT_GROUP):
    """Convenience: (B, T, D) @ (D, N) with QCD semantics."""
    b, t, d = x.shape
    y = quantized_matmul(x.reshape(b * t, d), w, a_bits, w_bits, g_bits,
                         group_size)
    return y.reshape(b, t, -1)
