"""Quantize-Compute-Dequantize (QCD) matrix multiplication with fully
quantized backward pass — the paper's Sec. 2.3.

Every GEMM in the fine-tuning graph (forward *and* backward) runs on
GSE-quantized operands:

    fwd:  Y  = Q^-1( Q(X) @ Q(W) )
    bwd:  dX = Q^-1( Q(dY) @ Q(W)^T )
          dW = Q^-1( Q(X)^T @ Q(dY) )

Each operand is quantized **along the contraction axis of that particular
GEMM** (so W is grouped along K for the forward, along N for dX — this is the
standard FQT convention, cf. Jetfire), with the group-shared 5-bit exponent
of :mod:`repro.core.gse`.

Residual wire format (``residuals_packed=True`` — docs/gse-format.md §5)
------------------------------------------------------------------------
The tensors saved for the backward GEMMs are **packed GSE word streams**
(:class:`~repro.core.gse.PackedGSETensor`: b-bit bit-planar mantissas +
packed 5-bit shared exponents), produced by the fused quantize+pack path:

    qcd_xq : Q(X)   logical (..., K), grouped along K  — feeds dW
    qcd_wq : Q(W)^T logical (N, K),   grouped along K  — feeds dX

so the live residual footprint is ``b + 5/group`` bits/value instead of 16
(the paper's activation-memory claim as observable bytes). The backward
quantizes dY once (``g_bits``, grouped along N) and dispatches both GEMMs
through :mod:`repro.kernels.ops`: on TPU the packed-operand Pallas matmuls
with tile-local dequant (``gse_matmul_packed_nt/tn``); elsewhere an
exact-dequant jnp fallback that runs the *same* XLA matmuls as the
fake-quant simulation — loss and gradients are bit-identical between
``residuals_packed`` on/off when the bit-widths match. ``residual_bits``
stores the residuals at a different (lower) bit-width than the forward
operands (QFT-style low-bit activation checkpointing; parity then no longer
holds, by construction).

The leaf names ``qcd_xq``/``qcd_wq`` are what the remat policy in
``repro.models.model`` saves (``save_only_these_names``) — under
rematerialization the *only* per-GEMM tensors carried from forward to
backward are the packed words.

Simulation note (``residuals_packed=False``, the legacy A/B path): we
compute with fake-quantized fp32/bf16 operands and let XLA run the GEMM,
saving the fake-quantized tensors themselves as full-width residuals. On
TPU the same math lowers to the Pallas int8 MXU kernel
(``repro.kernels.gse_matmul``); fp32 accumulation differs from exact int32
accumulation by ~1e-7 relative — far below quantization noise. Tests
compare both paths.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.core.gse import (DEFAULT_GROUP, effective_group_size,
                            gse_fake_quant)
from repro.distributed.sharding import shard
from repro.kernels import ops

__all__ = ["quantized_matmul", "quantized_einsum_btd_dn",
           "effective_group_size"]


def _fq(x: jax.Array, bits: Optional[int], group_size: int) -> jax.Array:
    """Fake-quantize along the last (contraction) axis; bits=None = passthrough."""
    if bits is None:
        return x
    g = effective_group_size(x.shape[-1], group_size)
    return gse_fake_quant(x, bits, g)


def _quant_pack(x: jax.Array, bits: int, group_size: int):
    """Fused quantize+pack along the last axis at the effective group."""
    g = effective_group_size(x.shape[-1], group_size)
    return ops.gse_quantize_pack(x, bits, g)


def _name_leaves(t, name: str):
    """checkpoint_name every array leaf (word + exponent streams) so the
    remat policy can save the packed residual across the backward replay."""
    return jax.tree.map(lambda a: checkpoint_name(a, name), t)


def _shard_residual(p):
    """Word-planar pspec constraint for the activation-residual streams
    under SPMD: the leading (token) axis of the word rows follows the
    ``qcd_residual`` rule; the flat 5-bit exponent stream is a 1-D
    word-aligned split (every uint32 word is self-contained — same argument
    as the opt_state rule in repro.distributed.sharding)."""
    mw = shard(p.mantissa_words,
               *(("qcd_residual",) + (None,) * (p.mantissa_words.ndim - 1)))
    ew = shard(p.exponent_words, "qcd_residual")
    return type(p)(mw, ew, p.bits, p.group_size, p.shape)


def _use_packed(a_bits, w_bits, residuals_packed) -> bool:
    """The packed residual path needs both forward operands quantized
    (partially-quantized ablations keep the legacy full-width residuals)."""
    return bool(residuals_packed) and a_bits is not None and w_bits is not None


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7, 8))
def quantized_matmul(
    x: jax.Array,
    w: jax.Array,
    a_bits: Optional[int] = 6,
    w_bits: Optional[int] = 6,
    g_bits: Optional[int] = 6,
    group_size: int = DEFAULT_GROUP,
    residuals_packed: bool = False,
    residual_bits: Optional[int] = None,
    int_mac: bool = False,
) -> jax.Array:
    """``x @ w`` with GSE-quantized operands and gradients.

    Args:
      x: (..., K) activations — quantized to ``a_bits`` along K.
      w: (K, N) weights — quantized to ``w_bits`` along K (fwd) / N (bwd dX).
      g_bits: gradient bit-width for dY in the backward GEMMs.
      group_size: GSE group size (contraction-axis groups).
      residuals_packed: save the backward residuals Q(X)/Q(W) as packed GSE
        word streams (``b + 5/group`` bits/value) and run the backward GEMMs
        on the packed operands. Bit-identical to the fake-quant path at
        matching bits; requires ``a_bits`` and ``w_bits``.
      residual_bits: override the stored residual bit-width (None = operand
        bits; lower values trade gradient fidelity for residual bytes).
      int_mac: run the packed backward GEMMs (dX/dW) with realigned int32
        MACs instead of fp32 tile dequant (bounded tier — parity within the
        documented bound, not bit-exact; requires ``residuals_packed`` and
        only affects the kernel route). REPRO_INT_MAC=1/0 overrides.

    Any of the bit-widths may be None to keep that operand in full precision
    (used for ablations and the QLoRA BF16 baseline).
    """
    y, _ = _qmm_fwd(x, w, a_bits, w_bits, g_bits, group_size,
                    residuals_packed, residual_bits, int_mac)
    return y


def _qmm_fwd(x, w, a_bits, w_bits, g_bits, group_size, residuals_packed,
             residual_bits, int_mac=False):
    if _use_packed(a_bits, w_bits, residuals_packed):
        return _qmm_fwd_packed(x, w, a_bits, w_bits, group_size,
                               residual_bits)
    xq = _fq(x, a_bits, group_size)
    # w: (K, N); contraction axis K is first -> quantize along axis 0.
    # Named so the remat policy can SAVE the quantized weight instead of
    # re-running NF4-dequant + GSE-quant in the backward pass (§Perf iter 6).
    wq = checkpoint_name(_fq(w.T, w_bits, group_size).T, "qcd_wq")
    # bf16 GEMM output: the MXU accumulates fp32 internally regardless; a
    # bf16 result halves the all-reduce payload of row-parallel partials
    # (§Perf iteration 1 — was preferred_element_type=f32).
    if ops.qcd_f32_out():
        y = jnp.matmul(xq, wq, preferred_element_type=jnp.float32
                       ).astype(x.dtype)
    else:
        y = jnp.matmul(xq, wq)
    # Residuals: keep the *quantized* tensors — backward consumes Q(X), Q(W)
    # exactly as stored (paper's backward eqs reuse the forward Q(·);
    # re-quantizing per-use turned out to cost full-weight/activation
    # all-gathers in SPMD — §Perf iteration 2/3).
    return y, (xq, wq)


def _qmm_fwd_packed(x, w, a_bits, w_bits, group_size, residual_bits):
    """Forward with packed residuals: quantize+pack X along K and W^T along
    K once (fused kernel path for 32-aligned K), save ONLY the word
    streams, and compute Y from the packed operands."""
    rb_x = residual_bits or a_bits
    rb_w = residual_bits or w_bits
    xp = _shard_residual(_quant_pack(x, rb_x, group_size))
    wp = _quant_pack(w.T, rb_w, group_size)       # logical (N, K) along K
    xp = _name_leaves(xp, "qcd_xq")
    wp = _name_leaves(wp, "qcd_wq")
    if rb_x == a_bits and rb_w == w_bits:
        # the packed residual IS the forward operand: one quantization,
        # bit-identical to the fake-quant simulation on the fallback path
        y = ops.qcd_matmul_y(xp, wp, compute_dtype=x.dtype,
                             f32_out=ops.qcd_f32_out())
    else:
        # compute at operand precision, store at residual precision
        xq = _fq(x, a_bits, group_size)
        wq = _fq(w.T, w_bits, group_size).T
        if ops.qcd_f32_out():
            y = jnp.matmul(xq, wq, preferred_element_type=jnp.float32
                           ).astype(x.dtype)
        else:
            y = jnp.matmul(xq, wq)
    # zero-length dtype token: the backward dequantizes Q(X) in x.dtype to
    # reproduce the fake-quant op sequence exactly
    return y, (xp, wp, jnp.zeros((0,), x.dtype))


def _qmm_bwd(a_bits, w_bits, g_bits, group_size, residuals_packed,
             residual_bits, res, dy, int_mac=False):
    if _use_packed(a_bits, w_bits, residuals_packed):
        return _qmm_bwd_packed(g_bits, group_size, res, dy, int_mac)
    xq, wq = res
    dyq = _fq(dy, g_bits, group_size)                        # grouped along N
    # dX = Q(dY) @ Q(W)^T : contraction over N, reusing the forward-grouped
    # Q(W) per the paper's dL/dX equation (no per-use re-grouping).
    if ops.qcd_f32_out():
        dx = jnp.matmul(dyq, wq.T, preferred_element_type=jnp.float32
                        ).astype(dy.dtype)
    else:
        dx = jnp.matmul(dyq, wq.T)
    # dW = Q(X)^T @ Q(dY) : contraction over tokens, reusing forward Q(X)
    # and the N-grouped Q(dY). Grouping does not align with the contraction
    # axis here, so this GEMM runs as a bf16 MAC on hardware (dW is the
    # cheapest of the three GEMMs; DESIGN §4 note).
    x2 = xq.reshape(-1, xq.shape[-1])                         # (B, K)
    dy2 = dyq.reshape(-1, dyq.shape[-1])                      # (B, N)
    dw = jnp.matmul(x2.T, dy2, preferred_element_type=jnp.float32
                    ).astype(dy.dtype)
    return dx, dw


def _qmm_bwd_packed(g_bits, group_size, res, dy, int_mac=False):
    """Backward on packed residuals: quantize+pack dY once (grouped along
    N), then both GEMMs consume packed operands directly — on TPU through
    the transposed-contraction / token-contraction Pallas kernels, on the
    simulation path through the exact-dequant fallback (bit-identical to
    the fake-quant backward). ``int_mac`` selects the realigned-int32 MAC
    mode of the kernels (bounded tier; inert on the fallback)."""
    xp, wp, dt = res
    x_dtype = dt.dtype
    dyq = _quant_pack(dy, g_bits, group_size) if g_bits is not None else dy
    # dX = Q(dY) @ Q(W)^T : wp already stores the (N, K) transposed layout.
    dx = ops.qcd_matmul_dx(dyq, wp, compute_dtype=dy.dtype,
                           f32_out=ops.qcd_f32_out(), int_mac=int_mac)
    # dW = Q(X)^T @ Q(dY) : contraction over tokens.
    dw = ops.qcd_matmul_dw(xp, dyq, out_dtype=dy.dtype, x_dtype=x_dtype,
                           dy_dtype=dy.dtype, int_mac=int_mac)
    return dx, dw


def _qmm_bwd_wrap(a_bits, w_bits, g_bits, group_size, residuals_packed,
                  residual_bits, int_mac, res, dy):
    dx, dw = _qmm_bwd(a_bits, w_bits, g_bits, group_size, residuals_packed,
                      residual_bits, res, dy, int_mac)
    return (dx, dw)


quantized_matmul.defvjp(_qmm_fwd, _qmm_bwd_wrap)


def quantized_einsum_btd_dn(x, w, a_bits, w_bits, g_bits,
                            group_size=DEFAULT_GROUP,
                            residuals_packed=False, residual_bits=None,
                            int_mac=False):
    """Convenience: (B, T, D) @ (D, N) with QCD semantics."""
    b, t, d = x.shape
    y = quantized_matmul(x.reshape(b * t, d), w, a_bits, w_bits, g_bits,
                         group_size, residuals_packed, residual_bits,
                         int_mac)
    return y.reshape(b, t, -1)
