"""GSQ linear layer: frozen (NF4) base weight + GSE-quantized LoRA adapters,
with fully quantized forward/backward GEMMs (paper Sec. 2.3, Fig. 3).

    Y = Q^-1(Q(X) Q(DQ(W_nf4))^T) + s * Q^-1(Q(X) Q(A)^T Q(B)^T)

Parameters live in two pytree buckets so the optimizer only touches adapters:

    frozen = {"w": NF4Tensor | bf16 array, ...}
    train  = {"lora_a": (r, ic) fp32, "lora_b": (oc, r) fp32}

Module style: plain functions over pytrees (no flax dependency); every model
in ``repro.models`` builds its projections through :func:`gsq_linear`.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.nf4 import NF4Tensor, nf4_quantize, nf4_fake_quant
from repro.core.policy import QuantPolicy
from repro.core.qcd import quantized_matmul
from repro.core import fp8 as fp8mod


def init_gsq_linear(key, in_dim: int, out_dim: int, policy: QuantPolicy,
                    dtype=jnp.bfloat16, w_init_scale: Optional[float] = None):
    """Returns (frozen, train) param trees for one linear layer."""
    kw, ka = jax.random.split(key)
    scale = w_init_scale if w_init_scale is not None else in_dim ** -0.5
    w = jax.random.normal(kw, (in_dim, out_dim), jnp.float32) * scale
    if policy.base_w_nf4:
        frozen = {"w": nf4_quantize(w)}
    else:
        frozen = {"w": w.astype(dtype)}
    train = {}
    if policy.rank > 0:
        r = policy.rank
        # LoRA init: A ~ N(0, 1/in), B = 0 (adapter starts as identity).
        train = {
            "lora_a": jax.random.normal(ka, (in_dim, r), jnp.float32)
                      * (in_dim ** -0.5),
            "lora_b": jnp.zeros((r, out_dim), jnp.float32),
        }
    return frozen, train


def _base_weight(frozen, dtype):
    w = frozen["w"]
    if isinstance(w, NF4Tensor):
        return w.dequantize(dtype)
    return w.astype(dtype)


def _fp8_matmul(x, w, fmt, group):
    @jax.custom_vjp
    def mm(x, w):
        return jnp.matmul(fp8mod.fp8_fake_quant(x, fmt, group),
                          fp8mod.fp8_fake_quant(w.T, fmt, group).T)

    def fwd(x, w):
        return mm(x, w), (x, w)

    def bwd(res, dy):
        x, w = res
        dyq = fp8mod.fp8_fake_quant(dy, fmt, group)
        wq = fp8mod.fp8_fake_quant(w, fmt, group)          # along N
        dx = jnp.matmul(dyq, wq.T)
        x2 = x.reshape(-1, x.shape[-1])
        dy2 = dy.reshape(-1, dy.shape[-1])
        dw = jnp.matmul(fp8mod.fp8_fake_quant(x2.T, fmt, group),
                        fp8mod.fp8_fake_quant(dy2.T, fmt, group).T)
        return dx, dw.astype(w.dtype)

    mm.defvjp(fwd, bwd)
    return mm(x, w)


def _qmm(x, w, a_bits, w_bits, g_bits, policy: QuantPolicy):
    """Dispatch one GEMM through the policy's format."""
    if policy.fmt == "none" or a_bits is None:
        return jnp.matmul(x, w)
    if policy.fmt.startswith("fp8"):
        return _fp8_matmul(x, w, policy.fmt.split("_")[1], policy.group_size)
    return quantized_matmul(x, w, a_bits, w_bits, g_bits, policy.group_size,
                            policy.residuals_packed, policy.residual_bits,
                            policy.int_mac)


def apply_gsq_linear(frozen, train, x: jax.Array, policy: QuantPolicy,
                     dtype=jnp.bfloat16) -> jax.Array:
    """Forward (and, under grad, the paper's quantized backward).

    x: (..., in_dim) -> (..., out_dim). Leading dims are flattened for the
    GEMMs and restored.
    """
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(dtype)
    w = _base_weight(frozen, dtype)
    # Frozen base branch: stop_gradient on W; dX still flows through the
    # quantized GEMM's backward (paper's dL/dX includes the Q(W) term).
    y = _qmm(x2, jax.lax.stop_gradient(w),
             policy.a_bits, policy.w_bits, policy.g_bits, policy)
    if train:
        a = train["lora_a"].astype(dtype)
        b = train["lora_b"].astype(dtype)
        s = policy.lora_alpha / max(policy.rank, 1)
        # low-rank branch: both GEMMs quantized at adapter_bits.
        h = _qmm(x2, a, policy.adapter_bits, policy.adapter_bits,
                 policy.adapter_bits, policy)
        y = y + s * _qmm(h, b, policy.adapter_bits, policy.adapter_bits,
                         policy.adapter_bits, policy)
    return y.reshape(*lead, -1).astype(dtype)


def merge_lora(frozen, train, policy: QuantPolicy, dtype=jnp.bfloat16):
    """W_eff = W + s·A@B — for export / serving without adapter GEMMs."""
    w = _base_weight(frozen, jnp.float32)
    if train:
        s = policy.lora_alpha / max(policy.rank, 1)
        w = w + s * (train["lora_a"] @ train["lora_b"])
    return w.astype(dtype)


def gsq_param_count(in_dim: int, out_dim: int, rank: int) -> dict:
    return {"base": in_dim * out_dim, "adapter": rank * (in_dim + out_dim)}
