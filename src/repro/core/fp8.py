"""FP8 (E4M3 / E5M2) simulated quantization — the paper's Table 2/13 baseline.

Uses ml_dtypes' float8 types (bit-exact casts) with per-group absmax scaling
to the format's max-normal, mirroring how FP8 training frameworks scale
tensors (per-tensor or per-group delayed scaling). Grouped scaling makes the
comparison to GSE apples-to-apples at equal metadata overhead.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import ml_dtypes

_FMT = {
    "e4m3": (jnp.float8_e4m3fn, 448.0),
    "e5m2": (jnp.float8_e5m2, 57344.0),
}


@partial(jax.jit, static_argnames=("fmt", "group_size"))
def fp8_fake_quant(x: jax.Array, fmt: str = "e4m3",
                   group_size: int = 32) -> jax.Array:
    """Quantize-dequantize ``x`` to FP8 along its last axis with per-group
    absmax scaling (group_size=None/0 for per-tensor)."""
    dt, fmax = _FMT[fmt]
    xf = jnp.asarray(x, jnp.float32)
    if group_size:
        k = xf.shape[-1]
        g = group_size if k % group_size == 0 else 1
        xg = xf.reshape(*xf.shape[:-1], k // g, g)
        amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
        scale = jnp.where(amax > 0, amax / fmax, 1.0)
        y = (xg / scale).astype(dt).astype(jnp.float32) * scale
        return y.reshape(xf.shape).astype(x.dtype)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / fmax, 1.0)
    return ((xf / scale).astype(dt).astype(jnp.float32) * scale).astype(x.dtype)


def fp8_quantization_error(x: jax.Array, fmt: str = "e4m3",
                           group_size: int = 32) -> dict:
    xf = jnp.asarray(x, jnp.float32)
    xq = fp8_fake_quant(xf, fmt, group_size)
    err = xf - xq
    mse = jnp.mean(err ** 2)
    sig = jnp.mean(xf ** 2)
    return {"mse": mse,
            "sqnr_db": 10.0 * jnp.log10(jnp.where(mse > 0, sig / mse, jnp.inf))}
