"""mamba2-2.7b [ssm]: 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128 — SSD. [arXiv:2405.21060]"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab=50280, ssm_state=128,
        ssm_expand=2, ssm_head_dim=64, ssm_groups=1, ssm_chunk=256,
        tie_embeddings=False, vocab_pad_multiple=2048)


def reduced():
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16,
        ssm_chunk=16, vocab=211, vocab_pad_multiple=64)
