"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads; SWA everywhere except
3 full-attention layers (first/middle/last). [arXiv:2411.13676]"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="hymba-1.5b", family="hybrid", hybrid=True, n_layers=32,
        d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64, d_ff=5504,
        vocab=32001, act="silu", ssm_state=16, ssm_expand=2,
        ssm_head_dim=64, ssm_groups=1, ssm_chunk=256, sliding_window=1024,
        global_attn_layers=(0, 15, 31), vocab_pad_multiple=2048)


def reduced():
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
        sliding_window=8, global_attn_layers=(0,), vocab=211,
        vocab_pad_multiple=64)
