"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe", n_layers=24, d_model=1024,
        n_heads=16, n_kv_heads=8, d_ff=512, vocab=49155, act="silu",
        n_experts=32, top_k=8, moe_d_ff=512, tie_embeddings=True,
        vocab_pad_multiple=2048)


def reduced():
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        moe_d_ff=32, n_experts=4, top_k=2, vocab=211, vocab_pad_multiple=64)
