"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA, tied embeddings. [hf:ibm-granite/granite-3.0-2b-base]"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="granite-3-2b", family="dense", n_layers=40, d_model=2048,
        n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155, act="silu",
        tie_embeddings=True, rope_theta=10000.0, vocab_pad_multiple=2048)


def reduced():
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
        vocab=211, vocab_pad_multiple=64)
