"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling (frontend stub feeds merged text+patch
embeddings). [hf:llava-hf/llava-v1.6-*]"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000, act="silu",
        rope_theta=5_000_000.0, frontend="vlm", vocab_pad_multiple=2048)


def reduced():
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
        vocab=211, vocab_pad_multiple=64)
