"""gemma-7b [dense]: 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000 — GeGLU, head_dim=256, tied embeddings. [arXiv:2403.08295]"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="gemma-7b", family="dense", n_layers=28, d_model=3072,
        n_heads=16, n_kv_heads=16, head_dim=256, d_ff=24576, vocab=256000,
        act="gelu", tie_embeddings=True, rope_theta=10000.0,
        vocab_pad_multiple=2048)


def reduced():
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=128, vocab=211, vocab_pad_multiple=64)
