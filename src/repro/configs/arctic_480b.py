"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual. [hf:Snowflake/snowflake-arctic-base]"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="arctic-480b", family="moe", n_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000, act="silu",
        n_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
        vocab_pad_multiple=2048)


def reduced():
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=64,
        moe_d_ff=64, n_experts=8, top_k=2, vocab=211, vocab_pad_multiple=64)
