"""qwen2-1.5b [dense]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — GQA, QKV bias, tied embeddings. [arXiv:2407.10671]"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="qwen2-1.5b", family="dense", n_layers=28, d_model=1536,
        n_heads=12, n_kv_heads=2, d_ff=8960, vocab=151936, act="silu",
        qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
        vocab_pad_multiple=2048)


def reduced():
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=211, vocab_pad_multiple=64)
