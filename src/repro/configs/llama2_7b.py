"""LLaMA-2-7B — the paper's own primary subject (Tab. 1/8): 32L d_model=4096
32H MHA d_ff=11008 vocab=32000. Used by the paper-validation benchmarks and
as the memory-model reference."""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="llama2-7b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=32, d_ff=11008, vocab=32000, act="silu",
        vocab_pad_multiple=2048)


def reduced():
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=211, vocab_pad_multiple=64)
