"""whisper-small [audio]: 12L d_model=768 12H (GQA kv=12) d_ff=3072
vocab=51865 — enc-dec, conv frontend (stub). [arXiv:2212.04356]"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="whisper-small", family="encdec", is_encoder_decoder=True,
        n_layers=12, n_encoder_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab=51865, act="gelu_mlp",
        norm_eps=1e-5, causal=True, encoder_len=1500, frontend="audio",
        vocab_pad_multiple=2048)


def reduced():
    return dataclasses.replace(
        config(), n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=211, encoder_len=16,
        vocab_pad_multiple=64)
