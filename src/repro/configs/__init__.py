"""Architecture registry: one module per assigned architecture (exact public
configs) + the paper's own LLaMA-2-7B-like default. ``get_config(name)``
returns the full ModelConfig; ``reduced_config(name)`` returns the same
family scaled down for CPU smoke tests."""
from __future__ import annotations

import dataclasses
import importlib

ARCHS = [
    "whisper_small", "llava_next_34b", "granite_3_2b", "qwen2_1_5b",
    "gemma_7b", "qwen3_14b", "mamba2_2_7b", "granite_moe_1b_a400m",
    "arctic_480b", "hymba_1_5b", "llama2_7b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str):
    mod = importlib.import_module(
        f"repro.configs.{_ALIAS.get(name, name)}")
    return mod.config()


def reduced_config(name: str):
    """CPU-scale config of the same family (smoke tests)."""
    mod = importlib.import_module(
        f"repro.configs.{_ALIAS.get(name, name)}")
    return mod.reduced()


def all_arch_names(include_paper_default: bool = False):
    out = [a for a in ARCHS if a != "llama2_7b"]
    return out + (["llama2_7b"] if include_paper_default else [])
