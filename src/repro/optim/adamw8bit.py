"""Block-wise low-bit AdamW (Dettmers et al., ICLR'22) — the paper's
optimizer ("8-bits AdamW ... in bfloat16 precision", Sec. 3 Training
Details) with its moments stored on the **packed GSE substrate**.

Moment storage: each moment tensor is flattened, padded to a multiple of
``BLOCK``, GSE-quantized along the flat axis (b-bit symmetric mantissas +
one shared 5-bit exponent per ``group`` values) and held as a
:class:`~repro.core.gse.PackedGSETensor` — real bit-planar uint32 words in
HBM, ``b + 5/group`` bits per moment value, the same wire format as packed
weights / KV / checkpoints. The second moment is stored in the **sqrt
domain** (halves the dynamic range and puts the quantization error directly
in the denominator's units — the cheap stand-in for Dettmers' dynamic
code). Re-quantization on the update hot path runs the fused quantize+pack
Pallas kernel (``repro.kernels.gse_quant_pack``): amax → exponent →
mantissa → bit-planar words in one VMEM pass, no int8 intermediate in HBM.

``m_bits`` / ``v_bits`` are configurable per-moment (default 8, matching
the paper's 8-bit optimizer accounting); master params stay fp32. Only
*trainable* leaves (the LoRA adapters) carry state; frozen NF4 base weights
carry none, which is where the paper's ~50 % fine-tune memory saving comes
from.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.gse import (EXP_BITS, PackedGSETensor, qmax_for_bits)

BLOCK = 256     # flat moments pad to this (rows of the kernel's 2-D tiling)


def _pad_len(n: int) -> int:
    return (-n) % BLOCK


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class PackedMoment:
    """One optimizer moment in packed GSE storage.

    ``packed`` holds the padded flat stream (shape ``(n + pad,)``); ``n``
    (static) is the true value count, so diagnostics can report the logical
    footprint with the BLOCK-padding tail excluded.
    """
    packed: PackedGSETensor
    n: int

    def tree_flatten_with_keys(self):
        return (((jax.tree_util.GetAttrKey("packed"), self.packed),),
                (self.n,))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])

    def values(self) -> jax.Array:
        """Dequantized flat fp32 moment values, padding stripped."""
        return self.packed.dequantize(jnp.float32)[: self.n]

    def nbytes_logical(self) -> int:
        """b-bit + shared-exponent bytes for the *unpadded* n values."""
        g = self.packed.group_size
        return (self.n * self.packed.bits + (-(-self.n // g)) * EXP_BITS
                + 7) // 8


class Adam8State(NamedTuple):
    m: Any          # tree of PackedMoment (b-bit first moment)
    v: Any          # tree of PackedMoment (b-bit sqrt second moment)
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamW8bit:
    lr: float = 1e-5
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    warmup_steps: int = 100          # paper: linear warmup of 100 steps
    schedule: str = "constant"       # paper: constant LR
    total_steps: int = 0             # cosine horizon (0 = constant)
    m_bits: int = 8                  # first-moment mantissa bits
    v_bits: int = 8                  # sqrt-second-moment mantissa bits
    group: int = 32                  # values per shared 5-bit exponent

    def __post_init__(self):
        # fail at the misconfiguration site: moments are padded to BLOCK
        # and grouped along the flat axis, so group must divide BLOCK (a
        # bad group otherwise only surfaces deep in gse internals on the
        # first values()/update call)
        if self.group <= 0 or BLOCK % self.group != 0:
            raise ValueError(
                f"group must divide BLOCK={BLOCK}, got {self.group}")
        qmax_for_bits(self.m_bits)       # validates 2 <= bits <= 8
        qmax_for_bits(self.v_bits)

    def _quantize_moment(self, x: jax.Array, bits: int) -> PackedMoment:
        """Flat fp32 (n,) -> PackedMoment via the fused quantize+pack
        kernel (pads to BLOCK; the pad tail quantizes to exact zeros)."""
        from repro.kernels.ops import gse_quantize_pack
        n = x.shape[0]
        xp = jnp.pad(x, (0, _pad_len(n)))
        return PackedMoment(gse_quantize_pack(xp, bits, self.group), n)

    def _zero_moment(self, n: int, bits: int) -> PackedMoment:
        """Packed all-zero moment, constructed directly: zero groups pin to
        EXP_MIN (biased 0 -> zero exponent words) and mantissa 0 is
        offset-binary ``2^(b-1)`` — one all-ones MSB plane, zero lower
        planes, laid out plane-major (docs/gse-format.md §3.1/§3.3)."""
        from repro.core.gse import mantissa_offset
        n_pad = n + _pad_len(n)
        u_zero = mantissa_offset(bits)
        plane = [jnp.uint32(0xFFFFFFFF if (u_zero >> (bits - 1 - p)) & 1
                            else 0)
                 for p in range(bits)]
        mw = jnp.repeat(jnp.stack(plane), n_pad // 32)
        ngroups = n_pad // self.group
        ew = jnp.zeros(((-(-ngroups // 32)) * EXP_BITS,), jnp.uint32)
        return PackedMoment(
            PackedGSETensor(mw, ew, bits, self.group, (n_pad,)), n)

    def init(self, params) -> Adam8State:
        return Adam8State(
            m=jax.tree.map(lambda p: self._zero_moment(p.size, self.m_bits),
                           params),
            v=jax.tree.map(lambda p: self._zero_moment(p.size, self.v_bits),
                           params),
            step=jnp.zeros((), jnp.int32))

    def current_lr(self, step):
        # ``update`` already advances step = state.step + 1 before calling;
        # warmup therefore ramps 1/W, 2/W, ... and reaches full LR exactly
        # at step == warmup_steps (the old (step + 1)/W skipped the first
        # fraction and saturated one step early).
        warm = jnp.minimum(1.0, step / max(self.warmup_steps, 1))
        lr = self.lr * warm
        if self.schedule == "cosine" and self.total_steps:
            prog = jnp.clip((step - self.warmup_steps)
                            / max(self.total_steps - self.warmup_steps, 1),
                            0.0, 1.0)
            lr = lr * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return lr

    def update(self, grads, state: Adam8State, params):
        """Returns (new_params, new_state)."""
        step = state.step + 1
        lr = self.current_lr(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, mom, vom):
            gf = g.reshape(-1).astype(jnp.float32)
            m = mom.values() * self.b1 + (1 - self.b1) * gf
            # v is stored as sqrt(v) (packed GSE in the sqrt domain)
            v = vom.values() ** 2 * self.b2 + (1 - self.b2) * gf * gf
            mhat = m / b1c
            vhat = v / b2c
            pf = p.reshape(-1).astype(jnp.float32)
            newp = pf - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                              + self.weight_decay * pf)
            return (newp.reshape(p.shape).astype(p.dtype),
                    self._quantize_moment(m, self.m_bits),
                    self._quantize_moment(jnp.sqrt(v), self.v_bits))

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        is_mom = lambda x: isinstance(x, PackedMoment)
        flat_m = jax.tree.flatten(state.m, is_leaf=is_mom)[0]
        flat_v = jax.tree.flatten(state.v, is_leaf=is_mom)[0]
        outs = [upd(*args) for args in
                zip(flat_p, flat_g, flat_m, flat_v)]
        newp = treedef.unflatten([o[0] for o in outs])
        new_state = Adam8State(
            m=treedef.unflatten([o[1] for o in outs]),
            v=treedef.unflatten([o[2] for o in outs]),
            step=step)
        return newp, new_state

    def state_nbytes(self, state: Adam8State) -> int:
        """Logical packed state footprint in bytes: b-bit mantissas plus
        amortized shared exponents for exactly ``param.size`` values per
        moment — BLOCK-padding tail bytes excluded, so the figure matches
        the analytic ``(bits + 5/group) / 8`` bytes/value accounting used
        by ``benchmarks/memory_model.py``."""
        moments = jax.tree.leaves(
            (state.m, state.v),
            is_leaf=lambda x: isinstance(x, PackedMoment))
        return sum(mom.nbytes_logical() for mom in moments)
