"""Block-wise 8-bit AdamW (Dettmers et al., ICLR'22) — the paper's optimizer
("8-bits AdamW ... in bfloat16 precision", Sec. 3 Training Details).

Optimizer moments are stored as int8 with one fp32 absmax scale per block of
256 values; master params stay fp32. We use linear absmax block quantization
(Dettmers uses a dynamic-tree code; linear absmax is within noise for the
adapter-scale states this framework trains and keeps the update jit-friendly
— noted in DESIGN §8).

Only applied to *trainable* leaves (the LoRA adapters); frozen NF4 base
weights carry no optimizer state, which is where the paper's ~50 % fine-tune
memory saving comes from.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_len(n: int) -> int:
    return (-n) % BLOCK


def _q8(x: jax.Array, signed: bool = True):
    """Blockwise absmax int8 quantization of a flat fp32 array."""
    n = x.shape[0]
    xp = jnp.pad(x, (0, _pad_len(n))).reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(xp), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xp / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0]


def _dq8(q: jax.Array, scale: jax.Array, n: int):
    xp = q.reshape(-1, BLOCK).astype(jnp.float32) * scale[:, None]
    return xp.reshape(-1)[:n]


class Adam8State(NamedTuple):
    m_q: Any          # tree of int8
    m_s: Any          # tree of fp32 block scales
    v_q: Any
    v_s: Any
    step: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamW8bit:
    lr: float = 1e-5
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    warmup_steps: int = 100          # paper: linear warmup of 100 steps
    schedule: str = "constant"       # paper: constant LR

    def init(self, params) -> Adam8State:
        def zq(p):
            n = p.size + _pad_len(p.size)
            return jnp.zeros((n,), jnp.int8)

        def zs(p):
            n = (p.size + _pad_len(p.size)) // BLOCK
            return jnp.zeros((n,), jnp.float32)

        return Adam8State(
            m_q=jax.tree.map(zq, params), m_s=jax.tree.map(zs, params),
            v_q=jax.tree.map(zq, params), v_s=jax.tree.map(zs, params),
            step=jnp.zeros((), jnp.int32))

    total_steps: int = 0             # cosine horizon (0 = constant)

    def current_lr(self, step):
        warm = jnp.minimum(1.0, (step + 1) / max(self.warmup_steps, 1))
        lr = self.lr * warm
        if self.schedule == "cosine" and self.total_steps:
            prog = jnp.clip((step - self.warmup_steps)
                            / max(self.total_steps - self.warmup_steps, 1),
                            0.0, 1.0)
            lr = lr * (0.1 + 0.9 * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return lr

    def update(self, grads, state: Adam8State, params):
        """Returns (new_params, new_state)."""
        step = state.step + 1
        lr = self.current_lr(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, mq, ms, vq, vs):
            n = p.size
            gf = g.reshape(-1).astype(jnp.float32)
            m = _dq8(mq, ms, n) * self.b1 + (1 - self.b1) * gf
            # v is stored as sqrt(v) (8-bit linear absmax in the sqrt domain
            # — the cheap stand-in for Dettmers' dynamic code; halves the
            # dynamic range and puts the quantization error directly in the
            # denominator's units)
            v = _dq8(vq, vs, n) ** 2 * self.b2 + (1 - self.b2) * gf * gf
            mhat = m / b1c
            vhat = v / b2c
            pf = p.reshape(-1).astype(jnp.float32)
            newp = pf - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                              + self.weight_decay * pf)
            mq2, ms2 = _q8(m)
            vq2, vs2 = _q8(jnp.sqrt(v))
            return (newp.reshape(p.shape).astype(p.dtype), mq2, ms2, vq2, vs2)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_mq = treedef.flatten_up_to(state.m_q)
        flat_ms = treedef.flatten_up_to(state.m_s)
        flat_vq = treedef.flatten_up_to(state.v_q)
        flat_vs = treedef.flatten_up_to(state.v_s)
        outs = [upd(*args) for args in
                zip(flat_p, flat_g, flat_mq, flat_ms, flat_vq, flat_vs)]
        newp = treedef.unflatten([o[0] for o in outs])
        new_state = Adam8State(
            m_q=treedef.unflatten([o[1] for o in outs]),
            m_s=treedef.unflatten([o[2] for o in outs]),
            v_q=treedef.unflatten([o[3] for o in outs]),
            v_s=treedef.unflatten([o[4] for o in outs]),
            step=step)
        return newp, new_state

    def state_nbytes(self, state: Adam8State) -> int:
        """True 8-bit state footprint (diagnostics for the memory model)."""
        tot = 0
        for leaf in jax.tree.leaves((state.m_q, state.v_q)):
            tot += leaf.size
        for leaf in jax.tree.leaves((state.m_s, state.v_s)):
            tot += leaf.size * 4
        return tot
