"""Fault-tolerant checkpointing: atomic, mesh-agnostic, keep-last-k.

Arrays are saved *logically* (full value per leaf, path-keyed npz) with a
JSON manifest carrying step / data position / config fingerprint. Restore
``device_put``s each leaf against the *current* mesh's shardings — so a run
can come back on a different topology (elastic restart: fewer/more data
replicas) as long as the model axes still divide.

Atomicity: write into ``step_XXXX.tmp/`` then ``os.rename`` — a crash
mid-write never corrupts the latest valid checkpoint. ``latest()`` scans for
the newest complete manifest.

On a real multi-host pod each host writes only its addressable shards
(jax.experimental.multihost_utils); this container is single-process so the
full value path is exercised, and the manifest format already records the
logical→sharded mapping needed for the multi-host writer.

Packed GSE support (two flavors):

* Trees already containing :class:`~repro.core.gse.PackedGSETensor` leaves
  round-trip losslessly — the pytree flattens to its uint32 word arrays
  (``.../mantissa_words``, ``.../exponent_words``) and ``restore`` rebuilds
  against the ``like`` structure. Checkpoint bytes on disk equal the live
  packed bytes. This is also how the packed AdamW moments
  (``repro.optim.adamw8bit.PackedMoment`` wrapping a packed tensor) travel:
  optimizer state checkpoints at b-bit wire size and resumes bit-exactly.
* ``save(..., gse_bits=b)`` quantizes eligible float leaves to GSE and
  stores the packed words (b + 5/group bits/value on disk instead of 32).
  This is a **lossy** serving/deployment snapshot — restore transparently
  dequantizes back to the ``like`` leaf dtype. Training state one will
  resume from should keep the default lossless path.

Both flavors serve **every** narrower width from the one full-width
snapshot: ``restore(..., bits=b)`` slices each packed leaf's word stream
to its first b mantissa planes host-side (the MSB-first wire format makes
the prefix exactly the floor-truncated b-bit tensor — docs/gse-format.md
§7) before anything touches the device.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gse import (DEFAULT_GROUP, PackedGSETensor,
                            plane_prefix_words)
from repro.kernels.ops import gse_quantize_pack


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return p.name
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ---- save -----------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None,
             gse_bits: Optional[int] = None,
             gse_group: int = DEFAULT_GROUP,
             gse_min_size: int = 4096):
        """Write a checkpoint. With ``gse_bits`` set, float leaves of at
        least ``gse_min_size`` values whose last axis divides ``gse_group``
        are stored GSE bit-packed (lossy serving snapshot); restore
        dequantizes them transparently."""
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(tree)
        arrays = {}
        leaf_meta = {}
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            # jnp.issubdtype, not np: bf16 (ml_dtypes) is not an np.floating
            if (gse_bits is not None and arr.ndim >= 1
                    and jnp.issubdtype(arr.dtype, jnp.floating)
                    and arr.size >= gse_min_size
                    and arr.shape[-1] % gse_group == 0):
                # fused quantize+pack kernel: fp leaf -> b-bit words in one
                # pass (no int8 intermediate), identical wire bytes to the
                # old quantize-then-pack dispatch pair
                p = gse_quantize_pack(
                    jnp.asarray(arr, jnp.float32), gse_bits, gse_group)
                arrays[key + "#gsem"] = np.asarray(p.mantissa_words)
                arrays[key + "#gsee"] = np.asarray(p.exponent_words)
                leaf_meta[key] = {"shape": list(arr.shape),
                                  "dtype": str(arr.dtype),
                                  "gse": {"bits": gse_bits,
                                          "group": gse_group}}
                continue
            arrays[key] = arr
            leaf_meta[key] = {"shape": list(arr.shape),
                              "dtype": str(arr.dtype)}
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "__"): v for k, v in arrays.items()})
        manifest = {
            "step": step,
            "time": time.time(),
            "leaves": leaf_meta,
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)       # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---- load -----------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None,
                bits: Optional[int] = None) -> tuple:
        """Restore into the structure of ``like``. ``shardings`` (optional
        matching tree of NamedSharding) re-lays leaves on the current mesh —
        the elastic-restart path.

        ``bits=b`` is the **progressive-precision load**: every packed GSE
        leaf — :class:`PackedGSETensor` weights/optimizer moments in
        ``like`` and ``gse_bits`` snapshot leaves on disk — loads as the
        b-bit plane-prefix view of its full-width snapshot. The word
        stream is sliced to its first ``b`` planes host-side, straight off
        the npz mmap, so only ``b/stored`` of the mantissa bytes ever
        reach the device: one checkpoint serves every width
        (docs/gse-format.md §7). Bit-identical to ``with_bits(b)`` on a
        full restore. Non-packed leaves are unaffected. ``bits`` and
        ``shardings`` are mutually exclusive (prefix-loaded word planes
        have no logical-axis sharding to resolve)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        if bits is not None and shardings is not None:
            raise ValueError("restore(bits=...) does not compose with "
                             "shardings")
        is_packed_leaf = (None if bits is None else
                          (lambda x: isinstance(x, PackedGSETensor)))
        flat_like, treedef = jax.tree_util.tree_flatten_with_path(
            like, is_leaf=is_packed_leaf)
        shard_flat = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(flat_like))
        leaves = []
        for (pth, leaf), shd in zip(flat_like, shard_flat):
            slash_key = "/".join(_path_str(p) for p in pth)
            key = slash_key.replace("/", "__")
            lmeta = manifest["leaves"].get(slash_key, {})
            if bits is not None and isinstance(leaf, PackedGSETensor):
                # plane-prefix load: slice the stored word stream to its
                # first b planes while it is still a host npz array — the
                # wide stream is never device_put
                wkey = (slash_key + "/mantissa_words").replace("/", "__")
                ekey = (slash_key + "/exponent_words").replace("/", "__")
                words = plane_prefix_words(data[wkey], leaf.bits, bits)
                leaves.append(PackedGSETensor(
                    jax.device_put(jnp.asarray(words)),
                    jax.device_put(jnp.asarray(data[ekey])),
                    leaf.stored_bits, leaf.group_size, leaf.shape, bits))
                continue
            if "gse" in lmeta:          # stored bit-packed: dequantize back
                sb = lmeta["gse"]["bits"]
                words = data[key + "#gsem"]
                ab = sb
                if bits is not None and bits < sb:
                    words = plane_prefix_words(words, sb, bits)
                    ab = bits
                p = PackedGSETensor(
                    jnp.asarray(words),
                    jnp.asarray(data[key + "#gsee"]),
                    sb, lmeta["gse"]["group"],
                    tuple(lmeta["shape"]), ab)
                arr = np.asarray(p.dequantize(jnp.float32))
                if hasattr(leaf, "dtype"):
                    arr = arr.astype(leaf.dtype)
                leaves.append(jax.device_put(arr, shd) if shd is not None
                              else jax.device_put(arr))
                continue
            arr = data[key]
            if arr.dtype.kind == "V":   # np roundtrips ml_dtypes as raw void
                import ml_dtypes  # noqa: F401 (registers extension dtypes)
                arr = arr.view(np.dtype(
                    manifest["leaves"][slash_key]["dtype"]))
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.device_put(arr))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest["metadata"], manifest["step"]
