"""GSE-compressed gradient synchronization with error feedback.

Beyond-paper, but format-native (DESIGN §5): the paper quantizes gradients
for *compute*; we reuse the exact same Group-Shared-Exponent format to cut
*inter-pod* gradient bytes. Within a pod, XLA's data-parallel reduction runs
at full precision over fast ICI; across the slow pod-to-pod (DCI) links,
gradients travel as b-bit GSE mantissas + 5-bit/group shared exponents:

    1. exponent agreement:   e* = pmax(e_local)      (tiny: K/32 int8)
    2. mantissa exchange:    all_gather(packed u32)  (b/16 of bf16 bytes)
    3. local reduce:         g = mean_i(m_i) * 2^e*
    4. error feedback:       r <- g_local - dequant(quant(g_local)),
                             added back before the next round's quantize.

The on-wire mantissa payload is **bit-packed** (default): b-bit offset-
binary fields in uint32 plane words (repro.core.gse wire format), so the
all-gather moves b/8 bytes per value — b=5 gradients cost 5/16 of bf16
bytes on the DCI, not the 1/2 an int8 gather would. Packing int8 mantissas
that already fit in b bits is lossless, so ``packed=True/False`` are
numerically identical; ``packed=False`` keeps the legacy s8 all-gather
(visible as such in dry-run HLO, which is how the roofline collective term
credits the compression).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.gse import (EXP_MIN, EXP_MAX, ceil_log2, exp2_int,
                            pack_mantissas, qmax_for_bits, unpack_mantissas)


def _group_quantize_shared(g: jax.Array, e_shared: jax.Array, bits: int,
                           group: int):
    """Quantize with an externally agreed exponent (post-pmax)."""
    qmax = qmax_for_bits(bits)
    gg = g.reshape(-1, group)
    # exact 2^e (IEEE-754 bit assembly) — XLA's exp2 can be an ulp off for
    # integer args depending on fusion context, which would let the same
    # gradient quantize differently across programs (repro.core.gse).
    scale = exp2_int(e_shared)[:, None]
    m = jnp.clip(jnp.round(gg / scale), -qmax, qmax).astype(jnp.int8)
    return m


def _local_exponent(g: jax.Array, bits: int, group: int):
    qmax = qmax_for_bits(bits)
    gg = g.reshape(-1, group)
    amax = jnp.max(jnp.abs(gg), axis=-1)
    safe = jnp.where(amax > 0, amax, 1.0)
    # exact ceil(log2) from the fp32 bit pattern: XLA's log2 approximation
    # is fusion-dependent and can flip the shared exponent by one at exact
    # powers of two — the wire words would then differ between the jitted
    # train step and any reference computation of the same gradient.
    e = ceil_log2(safe / qmax)
    e = jnp.where(amax > 0, e, EXP_MIN)
    return jnp.clip(e, EXP_MIN, EXP_MAX).astype(jnp.int8)


def compressed_mean(g: jax.Array, residual: jax.Array, axis_name: str,
                    bits: int = 8, group: int = 32, packed: bool = True
                    ) -> Tuple[jax.Array, jax.Array]:
    """Cross-``axis_name`` mean of ``g`` through the GSE wire format, with
    error-feedback residual. Must run inside shard_map manual over
    ``axis_name``. Returns (mean_grad, new_residual).

    ``packed=True`` bit-packs the mantissas into uint32 plane words before
    the all_gather (b/8 bytes/value on the wire) and unpacks after —
    numerically identical to the unpacked exchange, just fewer DCI bytes.
    """
    shape = g.shape
    n = g.size
    pad = (-n) % group
    flat = jnp.pad(g.reshape(-1).astype(jnp.float32), (0, pad))
    flat = flat + jnp.pad(residual.reshape(-1), (0, pad))

    e_loc = _local_exponent(flat, bits, group)
    e_star = jax.lax.pmax(e_loc, axis_name)                      # int8 agree
    m = _group_quantize_shared(flat, e_star, bits, group)        # int8
    if packed:
        # b-bit words on the wire; int8 exists only locally pre/post gather
        words = pack_mantissas(m.reshape(-1), bits)              # uint32
        w_all = jax.lax.all_gather(words, axis_name)             # (P, nw)
        npods = w_all.shape[0]
        m_all = unpack_mantissas(w_all, bits, m.size)            # (P, n)
        m_all = m_all.reshape(npods, *m.shape)
    else:
        # legacy s8 all-gather (1 byte/value on the wire)
        m_all = jax.lax.all_gather(m, axis_name)                 # (P, n/g, g)
        npods = m_all.shape[0]
    msum = jnp.sum(m_all.astype(jnp.int32), axis=0)
    mean = (msum.astype(jnp.float32) * exp2_int(e_star)[:, None]) / npods
    # error feedback: what this shard failed to transmit
    sent = m.astype(jnp.float32) * exp2_int(e_star)[:, None]
    new_res = (flat.reshape(-1, group) - sent).reshape(-1)[:n]
    return mean.reshape(-1)[:n].reshape(shape), new_res.reshape(-1)[:n
                                                                    ].reshape(shape)


def compressed_tree_mean(grads: Any, residuals: Any, axis_name: str,
                         bits: int = 8, group: int = 32,
                         packed: bool = True):
    """Tree-mapped :func:`compressed_mean`."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    outs = [compressed_mean(g, r, axis_name, bits, group, packed)
            for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_residuals(params: Any):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
