"""Path-based parameter sharding inference.

Maps every leaf of the (frozen, train, opt_state) trees to a PartitionSpec
from its tree path + shape, under the divisibility guards of
``resolve_pspec``. This is the in_shardings source for the dry-run and the
trainer. Rules (DESIGN §5):

  embeddings      (V, d)            -> (vocab, -)
  unembed         (d, V)            -> (-, vocab)
  up-projections  (L, in, out)      -> (-, w_embed, ff)      # TP col-parallel
  down-projections(L, in, out)      -> (-, ff, w_embed)      # TP row-parallel
  MoE experts     (L, E, d, f)      -> (-, experts, w_embed, -)
  LoRA A          (L, in, r)        -> (-, w_embed, -)
  LoRA B          (L, r, out)       -> (-, -, ff)
  optimizer moments (packed flat)   -> (opt_state rule)      # ZeRO-1 style
  everything else                   -> replicated

``w_embed`` is None by default (pure TP) and ("data",) under the FSDP rules
used by the biggest archs (arctic/llava).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ShardingRules, resolve_pspec

_UP_NAMES = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj"}
_DOWN_NAMES = {"wo", "w_down", "out_proj"}


def _path_names(path) -> list:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(p.name)
        elif isinstance(p, jax.tree_util.SequenceKey):
            out.append(str(p.idx))
    return out


def _leaf_logical(names: list, shape) -> tuple:
    """Return the logical-axis tuple for one leaf (None entries replicate)."""
    nd = len(shape)
    rep = (None,) * nd
    stacked = "layers" in names or "enc_layers" in names
    lead = (None,) if stacked else ()
    body = nd - len(lead)
    moe = "moe" in names

    def pad(axes):
        axes = tuple(axes)
        if len(axes) != body:
            return rep
        return lead + axes

    if "embed" in names and nd == 2:
        return ("vocab", None)
    if "unembed" in names and nd == 2:
        return (None, "vocab")
    # inside a linear: leaf names are w / codes / lora_a / lora_b / qscale...
    owner = None
    for n in names:
        if n in _UP_NAMES:
            owner = "up"
        if n in _DOWN_NAMES:
            owner = "down"
    leaf = names[-1]
    if leaf in ("w", "codes"):
        if moe and body == 3:
            return pad(("experts", "w_embed", None) if owner == "up"
                       else ("experts", None, "w_embed"))
        if body == 2:
            return pad(("w_embed", "ff") if owner == "up"
                       else ("ff", "w_embed"))
    if leaf == "lora_a" and body == 2:
        return pad(("w_embed", None))
    if leaf == "lora_b" and body == 2:
        # UP-projections keep B's output dim sharded (the consumer is the
        # sharded hidden); DOWN-projections replicate B — sharding its
        # d_model output forced an adapter-output all-gather at every
        # residual merge (§Perf iterations 4/7)
        return pad((None, "ff")) if owner == "up" else rep
    if leaf == "router" and body == 2:
        return pad(("w_embed", None))
    if leaf == "qscale" and body == 1:
        return pad(("w_embed",))
    return rep


def infer_param_pspecs(tree: Any, mesh: Mesh, rules: ShardingRules):
    """PartitionSpec tree for a param tree (frozen or train)."""
    def one(path, leaf):
        names = _path_names(path)
        shape = getattr(leaf, "shape", ())
        logical = _leaf_logical(names, shape)
        return resolve_pspec(shape, logical, mesh, rules)
    return jax.tree_util.tree_map_with_path(one, tree)


def infer_param_shardings(tree: Any, mesh: Mesh, rules: ShardingRules):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        infer_param_pspecs(tree, mesh, rules))


def opt_state_pspecs(opt_state, mesh: Mesh, rules: ShardingRules):
    """ZeRO-1-ish placement for the packed AdamW state.

    Moments are flat word-planar uint32 streams (``PackedMoment`` wrapping
    a ``PackedGSETensor`` — bit-planar chunks of 32 values, each word one
    self-contained plane): the big ``mantissa_words`` streams shard over
    the ``opt_state`` rule axis when the word count divides; the tiny
    ``exponent_words`` streams and the step scalar replicate. Any
    word-aligned split is a valid storage sharding — consumers unpack
    locally after the gather XLA inserts."""
    def one(path, leaf):
        shape = getattr(leaf, "shape", ())
        names = _path_names(path)
        if (len(shape) == 1 and shape[0] > 0
                and names[-1] == "mantissa_words"):
            return resolve_pspec(shape, ("opt_state",), mesh, rules)
        return P()
    return jax.tree_util.tree_map_with_path(one, opt_state)
