"""Logical-axis sharding rules (MaxText-style) with divisibility guards.

Models annotate activations with *logical* axes ("batch", "heads", "ff", ...);
a :class:`ShardingRules` table maps logical → mesh axes. A dim is sharded only
if it divides evenly by the mesh-axis size — otherwise it is silently
replicated (e.g. qwen2's 12 Q-heads on a 16-way model axis). This keeps every
(arch × mesh) combination compile-clean while letting well-shaped archs get
full TP.

Usage:
    with use_sharding(mesh, rules):
        x = shard(x, "batch", "seq", None)      # inside jit-traced code
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisSpec = Union[None, str, Tuple[str, ...]]

_CTX: contextvars.ContextVar = contextvars.ContextVar("repro_sharding", default=None)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis → mesh-axis mapping.

    Defaults implement DP over (pod, data) and TP/EP over model — the
    production mesh of this framework. FSDP is expressed by pointing
    ``embed``/``ff_weight_in`` at ("data",) (see fsdp() preset).
    """
    batch: AxisSpec = ("pod", "data")
    seq: AxisSpec = None            # sequence parallelism off by default
    embed: AxisSpec = None          # activation d_model axis
    heads: AxisSpec = "model"
    kv_heads: AxisSpec = "model"
    head_dim: AxisSpec = None
    ff: AxisSpec = "model"
    vocab: AxisSpec = "model"
    experts: AxisSpec = "model"
    expert_cap: AxisSpec = None
    ssm_heads: AxisSpec = "model"
    ssm_state: AxisSpec = None
    lora_rank: AxisSpec = None
    # weight-only axes (FSDP-style sharding of replicated-in-TP weight dims)
    w_embed: AxisSpec = None
    # flat packed optimizer-moment word streams (ZeRO-1-style placement).
    # The streams are word-planar uint32 (bit-planar chunks of 32 values —
    # repro.core.gse docstring): every uint32 word is self-contained (one
    # bit-plane of one chunk), so any word-aligned 1-D split is valid
    # storage sharding; the divisibility guard in resolve_pspec handles
    # stream lengths that don't divide the data axis.
    opt_state: AxisSpec = ("pod", "data")
    # packed QCD backward residuals (repro.core.qcd, residuals_packed=True):
    # the activation residual's word rows carry the flattened token axis in
    # front, which follows the data-parallel batch split; the flat 5-bit
    # exponent stream splits word-aligned like opt_state. Weight residuals
    # (qcd_wq) are not annotated (replicated like the adapter weights).
    qcd_residual: AxisSpec = ("pod", "data")
    # paged packed-KV page pools (repro.serve.paging): the pool's leading
    # physical-page axis P takes the data-parallel split the planar cache
    # put on batch — pages are whole rows of word/exponent planes, so any
    # page-aligned split is valid storage sharding (same self-contained-
    # word argument as opt_state); the page table itself stays replicated.
    kv_pages: AxisSpec = ("pod", "data")

    @classmethod
    def single_pod(cls):
        return cls(batch=("data",), opt_state=("data",),
                   qcd_residual=("data",), kv_pages=("data",))

    @classmethod
    def fsdp(cls, multi_pod: bool = True):
        """Zero-3-ish: additionally shard weight d_model dims over data."""
        dp = ("pod", "data") if multi_pod else ("data",)
        return cls(batch=dp, w_embed=("data",), opt_state=dp,
                   qcd_residual=dp, kv_pages=dp)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    rules: ShardingRules


def shard_map_compat(f, mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map(..., check_vma=, axis_names=)``;
    older releases only have ``jax.experimental.shard_map.shard_map`` with
    ``check_rep=``/``auto=``. Replication checking is disabled on both
    paths (the compressed-gradient regions mix manual collectives the
    checker cannot see through).
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": False}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
            if axis_names is not None else frozenset())
    return _shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)


def strip_axes(rules: ShardingRules, *axes: str) -> ShardingRules:
    """Remove mesh axes from every rule (e.g. drop 'pod' inside a shard_map
    region where 'pod' is Manual)."""
    upd = {}
    for f in dataclasses.fields(rules):
        v = getattr(rules, f.name)
        if v is None or not isinstance(v, (str, tuple)):
            continue
        t = (v,) if isinstance(v, str) else v
        t2 = tuple(a for a in t if a not in axes)
        if t2 != t:
            upd[f.name] = (t2 if len(t2) > 1 else
                           (t2[0] if t2 else None))
    return dataclasses.replace(rules, **upd) if upd else rules


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[ShardingRules] = None):
    token = _CTX.set(ShardCtx(mesh, rules or ShardingRules()) if mesh else None)
    try:
        yield
    finally:
        _CTX.reset(token)


def current_ctx() -> Optional[ShardCtx]:
    return _CTX.get()


def _mesh_axis_size(mesh: Mesh, spec: AxisSpec) -> int:
    if spec is None:
        return 1
    axes = (spec,) if isinstance(spec, str) else spec
    n = 1
    for a in axes:
        if a not in mesh.shape:
            return 0      # axis not in this mesh -> treat as unshardable
        n *= mesh.shape[a]
    return n


def resolve_pspec(shape: Sequence[int], logical: Sequence[Optional[str]],
                  mesh: Mesh, rules: ShardingRules) -> P:
    """Build a PartitionSpec, dropping any axis that does not divide."""
    assert len(shape) == len(logical), (shape, logical)
    parts = []
    used: set = set()
    for dim, name in zip(shape, logical):
        if name is None:
            parts.append(None)
            continue
        spec = getattr(rules, name, None)
        if spec is None:
            parts.append(None)
            continue
        axes = (spec,) if isinstance(spec, str) else tuple(spec)
        if any(a in used for a in axes):
            parts.append(None)          # a mesh axis may appear only once
            continue
        size = _mesh_axis_size(mesh, spec)
        if size <= 1 or dim % size != 0:
            parts.append(None)          # divisibility guard -> replicate
            continue
        used.update(axes)
        parts.append(spec)
    return P(*parts)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate ``x`` with a sharding constraint derived from logical axes.

    No-op when no mesh context is active (single-device tests/benches).
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    pspec = resolve_pspec(x.shape, logical, ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, pspec))


def named_sharding(mesh: Mesh, rules: ShardingRules,
                   shape: Sequence[int],
                   logical: Sequence[Optional[str]]) -> NamedSharding:
    return NamedSharding(mesh, resolve_pspec(shape, logical, mesh, rules))
