"""Training runner: checkpoint-restart, preemption handling, straggler
watchdog, elastic resume. The orchestration layer a cluster scheduler talks
to.

Fault-tolerance model (DESIGN §5):
  * periodic checkpoints (atomic; keep-last-k) + step-exact data resume
    (the synthetic pipeline is a pure function of (seed, step)),
  * SIGTERM/SIGINT → finish the in-flight step, emergency-save, exit 0 so
    the scheduler restarts us cleanly on preemption,
  * straggler watchdog: EWMA of step wall-time; a step slower than
    ``straggler_factor``× the EWMA is logged with its timing (on a real
    cluster this feeds the reschedule signal; here it is observable state),
  * elastic restart: checkpoints are mesh-agnostic, restore re-lays leaves
    on whatever mesh the relaunched job builds (CheckpointManager.restore).
"""
from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, batch_at_step
from repro.optim.adamw8bit import AdamW8bit
from repro.train.step import TrainConfig, make_train_step, init_residuals

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1


class TrainingRunner:
    def __init__(self, cfg, policy, data_cfg: DataConfig, opt: AdamW8bit,
                 tcfg: TrainConfig, rcfg: RunnerConfig, mesh=None,
                 frozen=None, train=None, donate: bool = True):
        self.cfg, self.policy = cfg, policy
        self.data_cfg, self.opt, self.tcfg, self.rcfg = \
            data_cfg, opt, tcfg, rcfg
        self.mesh = mesh
        self.frozen, self.train = frozen, train
        self.opt_state = opt.init(train)
        # logical packed moment footprint (b + 5/group bits per value,
        # BLOCK padding excluded) — the quantity memory_model.py credits
        self.opt_state_nbytes = opt.state_nbytes(self.opt_state)
        log.info("optimizer state: %d packed bytes "
                 "(m_bits=%d v_bits=%d group=%d)",
                 self.opt_state_nbytes, opt.m_bits, opt.v_bits, opt.group)
        n_pods = mesh.shape.get("pod", 1) if mesh else 1
        self.residuals = init_residuals(train, n_pods) \
            if tcfg.compress_pod_grads else jax.tree.map(
                lambda p: np.zeros((0,), np.float32), train)
        self.step = 0
        self.ckpt = CheckpointManager(rcfg.checkpoint_dir,
                                      rcfg.keep_checkpoints)
        self._preempted = False
        self._ewma = None
        self.straggler_events = []
        self.metrics_history = []
        fn = make_train_step(cfg, policy, opt, tcfg, mesh)
        self._step_fn = jax.jit(fn, donate_argnums=(1, 2, 3)) \
            if donate else jax.jit(fn)

    # ---- fault tolerance hooks ------------------------------------------
    def install_signal_handlers(self):
        def handler(signum, frame):
            log.warning("preemption signal %s — will save and exit", signum)
            self._preempted = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def maybe_resume(self) -> bool:
        latest = self.ckpt.latest()
        if latest is None:
            return False
        state_like = {"train": self.train, "opt": self.opt_state,
                      "residuals": self.residuals}
        state, meta, step = self.ckpt.restore(latest, state_like)
        self.train = state["train"]
        self.opt_state = state["opt"]
        self.residuals = state["residuals"]
        self.step = step
        log.info("resumed from step %d", step)
        return True

    def save(self):
        self.ckpt.save(self.step,
                       {"train": self.train, "opt": self.opt_state,
                        "residuals": self.residuals},
                       metadata={"data_seed": self.data_cfg.seed,
                                 "policy": self.policy.label(),
                                 "opt_state_nbytes": self.opt_state_nbytes})

    # ---- main loop --------------------------------------------------------
    def run(self, until: Optional[int] = None,
            on_metrics: Optional[Callable] = None):
        until = until or self.rcfg.total_steps
        while self.step < until and not self._preempted:
            t0 = time.monotonic()
            batch = batch_at_step(self.data_cfg, self.step)
            batch = jax.tree.map(jax.numpy.asarray, batch)
            self.train, self.opt_state, self.residuals, metrics = \
                self._step_fn(self.frozen, self.train, self.opt_state,
                              self.residuals, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            self._watchdog(dt)
            self.step += 1
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = self.step
            m["step_time_s"] = dt
            self.metrics_history.append(m)
            if on_metrics:
                on_metrics(m)
            if self.step % self.rcfg.log_every == 0:
                log.info("step %d loss %.4f (%.3fs)", self.step, m["loss"], dt)
            if self.step % self.rcfg.checkpoint_every == 0:
                self.save()
        if self._preempted:
            self.save()            # emergency checkpoint
        return self.metrics_history

    def _watchdog(self, dt: float):
        if self._ewma is None:
            self._ewma = dt
            return
        if dt > self.rcfg.straggler_factor * self._ewma and self.step > 2:
            self.straggler_events.append({"step": self.step, "dt": dt,
                                          "ewma": self._ewma})
            log.warning("straggler step %d: %.3fs vs EWMA %.3fs",
                        self.step, dt, self._ewma)
        a = self.rcfg.ewma_alpha
        self._ewma = (1 - a) * self._ewma + a * dt
