"""Training step: masked LM loss, microbatched gradient accumulation,
optional GSE-compressed cross-pod gradient sync, low-bit AdamW update with
**packed** GSE moments.

``train_step`` is the function the train_* dry-run cells lower: it takes
(train_params, opt_state, residuals, batch) and returns updated state +
metrics, with every GEMM inside running the paper's QCD pipeline. The
``opt_state`` threaded through (and donated by the runner / dry-run jits)
is an :class:`~repro.optim.adamw8bit.Adam8State` whose moment leaves are
``PackedMoment`` pytrees — flat uint32 word streams in HBM at
``b + 5/group`` bits per moment value; the update re-quantizes them through
the fused quantize+pack Pallas kernel each step.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.models.config import ModelConfig
from repro.models import model as M
from repro.optim.adamw8bit import AdamW8bit, Adam8State
from repro.distributed.sharding import shard
from repro.distributed import compression as C


def lm_loss(train, frozen, batch, cfg: ModelConfig, policy: QuantPolicy):
    """Masked cross-entropy over next-token targets, fused per T-chunk so
    (B, T, V) logits are never materialized (big-vocab archs). fp32 lse."""
    x = M.forward_hidden(frozen, train, batch, cfg, policy)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    loss_sum, n_tok = M.fused_ce_loss(frozen, x, labels, mask, cfg)
    denom = jnp.maximum(n_tok, 1.0)
    loss = loss_sum / denom
    return loss, {"loss": loss, "tokens": denom}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    accum_steps: int = 1                  # microbatch count per step
    compress_pod_grads: bool = False      # GSE cross-pod gradient sync
    compress_bits: int = 8
    compress_packed: bool = True          # bit-packed u32 wire payload
    max_grad_norm: float = 1.0


def _microbatch(batch, i, n):
    """Slice microbatch i of n along the batch axis."""
    def sl(x):
        mb = x.shape[0] // n
        return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
    return jax.tree.map(sl, batch)


def accumulate_grads(train, frozen, batch, cfg: ModelConfig,
                     policy: QuantPolicy, accum_steps: int):
    """Mean loss/grads over ``accum_steps`` microbatches via lax.scan —
    activations live for one microbatch only (DESIGN §5 memory posture).

    With ``policy.residuals_packed`` the per-microbatch backward residuals
    the scan body carries between its forward and backward are the packed
    ``qcd_xq``/``qcd_wq`` word streams (b + 5/group bits per value — the
    remat policy in repro.models.model saves exactly those names), so the
    live residual footprint of a microbatch is the packed bytes
    ``benchmarks/memory_model.py`` reports, not bf16 tensors.

    Returns the same metrics dict on both paths: ``tokens`` accumulates
    across microbatches so it matches the single-shot count."""
    loss_grad = jax.value_and_grad(lm_loss, has_aux=True)
    if accum_steps <= 1:
        (loss, aux), grads = loss_grad(train, frozen, batch, cfg, policy)
        return loss, aux, grads

    def body(carry, i):
        g_acc, l_acc, t_acc = carry
        mb = _microbatch(batch, i, accum_steps)
        (loss, aux), grads = loss_grad(train, frozen, mb, cfg, policy)
        g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                             g_acc, grads)
        return (g_acc, l_acc + loss, t_acc + aux["tokens"]), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), train)
    (g_sum, l_sum, t_sum), _ = jax.lax.scan(
        body, (g0, jnp.zeros(()), jnp.zeros(())), jnp.arange(accum_steps))
    inv = 1.0 / accum_steps
    grads = jax.tree.map(lambda g: g * inv, g_sum)
    loss = l_sum * inv
    return loss, {"loss": loss, "tokens": t_sum}, grads


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def make_train_step(cfg: ModelConfig, policy: QuantPolicy, opt: AdamW8bit,
                    tcfg: TrainConfig, mesh=None):
    """Build the jit-able train_step(frozen, train, opt_state, residuals,
    batch) -> (train, opt_state, residuals, metrics).

    When ``compress_pod_grads`` is on (and the mesh has a pod axis > 1), the
    whole grad computation is shard_mapped *manually* over "pod": each pod
    computes gradients for its local batch slice at full ICI precision, then
    the pods exchange int8 GSE mantissas over the slow inter-pod links
    (compression.compressed_mean) with per-pod error-feedback residuals.
    Residual state is stored with a leading pod axis, sharded over "pod".
    """
    use_compress = (tcfg.compress_pod_grads and mesh is not None
                    and "pod" in mesh.shape and mesh.shape["pod"] > 1)

    def _grads(train, frozen, batch):
        return accumulate_grads(train, frozen, batch, cfg, policy,
                                tcfg.accum_steps)

    def train_step(frozen, train, opt_state: Adam8State, residuals, batch):
        if use_compress:
            from jax.sharding import PartitionSpec as P
            rep = jax.tree.map(lambda _: P(), (train, frozen))
            batch_specs = jax.tree.map(lambda _: P("pod"), batch)
            res_specs = jax.tree.map(lambda _: P("pod"), residuals)

            def per_pod(train, frozen, batch, res):
                from repro.distributed.sharding import (current_ctx,
                                                        strip_axes,
                                                        use_sharding)
                res = jax.tree.map(lambda r: r[0], res)      # drop pod dim
                ctx = current_ctx()
                # inside the manual-pod region, inner constraints must not
                # reference the (now Manual) pod axis
                inner_rules = strip_axes(ctx.rules, "pod") if ctx else None
                with use_sharding(ctx.mesh if ctx else None, inner_rules):
                    loss, aux, grads = _grads(train, frozen, batch)
                grads, res = C.compressed_tree_mean(
                    grads, res, "pod", tcfg.compress_bits,
                    packed=tcfg.compress_packed)
                loss = jax.lax.pmean(loss, "pod")
                res = jax.tree.map(lambda r: r[None], res)
                return loss, grads, res

            from repro.distributed.sharding import shard_map_compat
            loss, grads, residuals = shard_map_compat(
                per_pod, mesh,
                in_specs=(rep[0], rep[1], batch_specs, res_specs),
                out_specs=(P(), jax.tree.map(lambda _: P(), train),
                           res_specs),
                axis_names={"pod"})(train, frozen, batch, residuals)
        else:
            loss, aux, grads = _grads(train, frozen, batch)
        grads, gnorm = clip_by_global_norm(grads, tcfg.max_grad_norm)
        train, opt_state = opt.update(grads, opt_state, train)
        # opt_state.step is already the post-update step, so this is the
        # exact LR the update above applied (update advances step first).
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt.current_lr(opt_state.step)}
        return train, opt_state, residuals, metrics

    return train_step


def init_residuals(train, n_pods: int = 1):
    """Per-pod error-feedback residual tree (leading pod axis)."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), train)
