"""Continuous-batching scheduler over the paged packed-KV pool.

The engine keeps a fixed batch of ``slots`` decode lanes stepping together
through the jitted :func:`repro.serve.engine.decode_step` (one trace, one
executable — batch shape never changes) while requests of ragged
prompt/output lengths flow through the lanes:

* **admission**: a pending request takes a free slot when the
  :class:`~repro.serve.paging.PageAllocator` can cover its page span
  (``alloc`` returning ``None`` is backpressure — the request waits for
  evictions). The prompt prefits **solo** in a batch-1 temp cache, packs
  to planar planes, and scatters whole pages into the pool; the slot's
  page-table row and per-sequence index are set host-side.
* **decode**: every step runs all slots; per-request sampling params
  (greedy / temperature / top-k, seeded per request+step) pick each lane's
  next token; per-request stop tokens and ``max_new`` finish lanes
  independently.
* **eviction**: a finished lane's pages go back to the free list and its
  page-table row retargets the trash page, so the lane keeps stepping
  harmlessly (stale writes land in the trash) until a new request takes
  it over.

With ``kv_quant_bits=None`` the same loop runs over the contiguous fp
cache (no pages — eviction just frees the slot): the per-sequence offset
vector path of ``decode_step`` is what makes the ragged batch correct.

Token identity: a request decoded through this engine — admitted and
evicted mid-flight, its pages recycled from earlier requests — produces
exactly the tokens of its solo :func:`~repro.serve.engine.greedy_generate`
run at cache length ``page_size · max_pages_per_slot`` (asserted in
tests/test_serve_continuous.py): prefill quantization, in-place appends,
tile boundaries and masked-tile no-ops are all bit-identical.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gse import DEFAULT_GROUP
from repro.core.policy import QuantPolicy
from repro.models.config import ModelConfig
from repro.serve import engine as E
from repro.serve import paging


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling: ``temperature <= 0`` is greedy; ``top_k > 0``
    restricts sampling to the k highest logits; ``seed`` decorrelates
    requests (each step reseeds deterministically from request uid, step
    and this seed).

    ``kv_bits`` (packed engines only) is the request's KV **read** width:
    its lane attends through the first ``kv_bits`` mantissa planes of the
    pool's stored-width pages (plane-prefix view, docs/gse-format.md §7).
    Storage is untouched — every lane's writes stay at the pool width, so
    lanes at different ``kv_bits`` batch together in one fused decode
    block. ``None`` reads the full stored width."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    kv_bits: Optional[int] = None


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                       # (T,) int32 token ids
    max_new: int
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    stop_token: Optional[int] = None


@dataclasses.dataclass
class _Slot:
    req: Request
    out: List[int]
    pages: List[int]
    steps: int = 0


# ---------------------------------------------------------------------------
# Shared jitted programs. These live at module scope (keyed on the hashable
# cfg/policy/bits) so every engine instance over the same model reuses one
# compiled executable — a fresh engine must not recompile. Two dispatch
# shapes cover the whole serving loop:
#
# * **decode block**: ``k`` decode+sample steps fused in one ``lax.scan``
#   dispatch (multi-step scheduling). The scheduler only needs to run
#   host-side logic when a lane can finish or a slot can turn over, and
#   ``k = min(remaining)`` over active lanes guarantees neither happens
#   mid-block — so sampled tokens feed back device-side and the KV cache
#   stays in-place for the whole block (the cache argument is donated).
#   ``k`` is rounded down to a power of two to bound retraces.
# * **admission**: prefill + planar pack + page scatter + index write +
#   first-token sample, one dispatch per admitted request.
# ---------------------------------------------------------------------------

def _sample_rows(logits, temps, topks, seeds):
    """Per-row sampling: greedy when ``temps[i] <= 0``, else temperature
    (+ optional top-k) categorical seeded per row."""
    v = logits.shape[-1]

    def one(lg, tmp, k, seed):
        scaled = lg / jnp.maximum(tmp, 1e-6)
        kk = jnp.where(k > 0, jnp.minimum(k, v), v)
        cut = jnp.sort(scaled)[v - kk]
        masked = jnp.where(scaled >= cut, scaled, -jnp.inf)
        samp = jax.random.categorical(jax.random.PRNGKey(seed), masked)
        return jnp.where(tmp <= 0.0, jnp.argmax(lg, -1),
                         samp).astype(jnp.int32)
    return jax.vmap(one)(logits, temps, topks, seeds)


@functools.lru_cache(maxsize=None)
def _decode_block_fn(cfg: ModelConfig, policy: QuantPolicy):
    """(fz, tr, tok (B,1), cache, temps (B,), topks (B,), seeds (k, B))
    -> (tokens (k, B), cache). One trace per block length k."""
    def f(fz, tr, tok, cache, temps, topks, seeds):
        def body(carry, seed_row):
            tok, cache = carry
            logits, cache = E.decode_step(fz, tr, tok, cache, cfg, policy)
            nt = _sample_rows(logits, temps, topks, seed_row)
            return (nt[:, None], cache), nt
        (_, cache), toks = jax.lax.scan(body, (tok, cache), seeds)
        return toks, cache
    return jax.jit(f, donate_argnums=(3,))


@functools.lru_cache(maxsize=None)
def _admit_packed_fn(cfg: ModelConfig, policy: QuantPolicy, bits: int,
                     group: int, s_cap: int):
    """Whole packed admission in one dispatch: solo prefill at the full
    slot capacity, planar pack, full-page scatter into the pool, slot
    index write, first-token sample."""
    def f(fz, tr, prompt, cache, ids, slot, temps, topks, seeds):
        tmp = E.init_decode_cache(cfg, 1, s_cap)
        logits, tmp = E.prefill(fz, tr, {"tokens": prompt}, tmp, cfg,
                                policy)
        planar = E.pack_decode_cache_planar(tmp, bits, group)
        out = paging.scatter_prefill_pages(cache, planar, ids)
        out["index"] = out["index"].at[:, slot].set(prompt.shape[1])
        return _sample_rows(logits, temps, topks, seeds), out
    return jax.jit(f, donate_argnums=(3,))


@functools.lru_cache(maxsize=None)
def _admit_fp_fn(cfg: ModelConfig, policy: QuantPolicy, s_cap: int):
    def f(fz, tr, prompt, cache, slot, temps, topks, seeds):
        tmp = E.init_decode_cache(cfg, 1, s_cap)
        logits, tmp = E.prefill(fz, tr, {"tokens": prompt}, tmp, cfg,
                                policy)
        out = dict(cache)
        for key in ("k", "v"):
            out[key] = cache[key].at[:, slot].set(tmp[key][:, 0])
        out["index"] = cache["index"].at[:, slot].set(prompt.shape[1])
        return _sample_rows(logits, temps, topks, seeds), out
    return jax.jit(f, donate_argnums=(3,))


class ContinuousBatchingEngine:
    """Fixed-width continuous batching over paged packed-KV (or the
    contiguous fp cache when ``kv_quant_bits`` is None)."""

    def __init__(self, fz, tr, cfg: ModelConfig, policy: QuantPolicy, *,
                 slots: int = 4, page_size: int = 16,
                 max_pages_per_slot: int = 4,
                 n_pages: Optional[int] = None,
                 kv_quant_bits: Optional[int] = None,
                 kv_group: int = DEFAULT_GROUP):
        self.fz, self.tr, self.cfg, self.policy = fz, tr, cfg, policy
        self.slots = slots
        self.page_size = page_size
        self.max_pages = max_pages_per_slot
        self.s_cap = page_size * max_pages_per_slot
        self.kv_quant_bits = kv_quant_bits
        self.kv_group = kv_group
        self.packed = kv_quant_bits is not None
        if self.packed:
            n_pages = n_pages or (paging.FIRST_PAGE
                                  + slots * max_pages_per_slot)
            self.allocator = paging.PageAllocator(n_pages, page_size)
            self.cache = paging.init_paged_cache(
                cfg, slots, n_pages, page_size, max_pages_per_slot,
                kv_quant_bits, kv_group)
            self._table = np.tile(paging.trash_page_row(max_pages_per_slot),
                                  (slots, 1))
            # per-lane extra plane shifts below the pool's stored width
            # (stored - kv_bits for a narrowed request, 0 = full width);
            # mirrored device-side exactly like the page table
            self._trunc = np.zeros((slots,), np.int32)
        else:
            self.allocator = None
            self.cache = E.init_decode_cache(cfg, slots, self.s_cap)
        self.queue: deque = deque()
        self.active: Dict[int, _Slot] = {}       # slot id -> lane state
        self.results: Dict[int, np.ndarray] = {}
        self.stats = {"steps": 0, "occupancy_sum": 0,
                      "page_util_sum": 0.0, "admitted": 0, "evicted": 0}
        # shared per-(cfg, policy) executables: a fresh engine over an
        # already-warm model pays zero compiles
        self._decode_block = _decode_block_fn(cfg, policy)
        self._admit_jit = (
            _admit_packed_fn(cfg, policy, kv_quant_bits, kv_group,
                             self.s_cap) if self.packed
            else _admit_fp_fn(cfg, policy, self.s_cap))

    # -- request intake ---------------------------------------------------

    def submit(self, req: Request) -> None:
        need = len(req.prompt) + req.max_new
        if need > self.s_cap:
            raise ValueError(f"request {req.uid} needs {need} rows > "
                             f"slot capacity {self.s_cap}")
        kvb = req.sampling.kv_bits
        if kvb is not None:
            # validated here at intake, not at trace time inside the fused
            # decode block — a bad width must bounce the one request, not
            # poison a compiled executable shared by every lane
            if not self.packed:
                raise ValueError(f"request {req.uid} sets kv_bits={kvb} "
                                 "but the engine serves the fp cache "
                                 "(kv_quant_bits=None)")
            if not 2 <= kvb <= self.kv_quant_bits:
                raise ValueError(
                    f"request {req.uid} kv_bits={kvb} outside [2, stored "
                    f"pool width {self.kv_quant_bits}] — the pool stores "
                    f"{self.kv_quant_bits}-bit planes; reads can only "
                    "take a plane prefix")
        if self.packed:
            npg = self.allocator.pages_for(need)
            if npg > self.allocator.n_allocatable:
                raise ValueError(f"request {req.uid} needs {npg} pages > "
                                 f"pool {self.allocator.n_allocatable}")
        self.queue.append(req)

    # -- sampling ---------------------------------------------------------

    @staticmethod
    def _seed(req: Request, steps: int) -> int:
        return (req.uid * 1000003 + steps * 7919
                + req.sampling.seed) % (2 ** 31)

    def _lane_params(self, slot_ids):
        """(temps, topks, seeds) numpy rows for ``slot_ids`` — greedy
        defaults for inactive lanes (their token is discarded)."""
        temps = np.zeros((len(slot_ids),), np.float32)
        topks = np.zeros((len(slot_ids),), np.int32)
        seeds = np.zeros((len(slot_ids),), np.int32)
        for i, s in enumerate(slot_ids):
            lane = self.active.get(s)
            if lane is None:
                continue
            temps[i] = lane.req.sampling.temperature
            topks[i] = lane.req.sampling.top_k
            seeds[i] = self._seed(lane.req, lane.steps)
        return temps, topks, seeds

    # -- admission / eviction --------------------------------------------

    def _free_slots(self):
        return [s for s in range(self.slots) if s not in self.active]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                return
            req = self.queue[0]
            pages: List[int] = []
            if self.packed:
                need = self.allocator.pages_for(len(req.prompt)
                                                + req.max_new)
                got = self.allocator.alloc(need)
                if got is None:              # backpressure: wait for evict
                    return
                pages = got
            self.queue.popleft()
            prompt = jnp.asarray(np.asarray(req.prompt, np.int32))[None]
            sp = req.sampling
            temps = np.asarray([sp.temperature], np.float32)
            topks = np.asarray([sp.top_k], np.int32)
            seeds = np.asarray([self._seed(req, 0)], np.int32)
            if self.packed:
                tok_arr, self.cache = self._admit_jit(
                    self.fz, self.tr, prompt, self.cache,
                    np.asarray(pages, np.int32), np.int32(slot),
                    temps, topks, seeds)
                self._table[slot] = paging.slot_page_row(pages,
                                                         self.max_pages)
                self._push_table()
                kvb = req.sampling.kv_bits
                self._trunc[slot] = (0 if kvb is None
                                     else self.kv_quant_bits - kvb)
                self._push_trunc()
            else:
                tok_arr, self.cache = self._admit_jit(
                    self.fz, self.tr, prompt, self.cache, np.int32(slot),
                    temps, topks, seeds)
            lane = _Slot(req=req, out=[], pages=pages)
            self.active[slot] = lane
            self.stats["admitted"] += 1
            tok = int(np.asarray(tok_arr)[0])
            lane.out.append(tok)
            lane.steps = 1
            if self._done(lane, tok):
                self._evict(slot)

    def _done(self, lane: _Slot, tok: int) -> bool:
        return (len(lane.out) >= lane.req.max_new
                or (lane.req.stop_token is not None
                    and tok == lane.req.stop_token))

    def _evict(self, slot: int) -> None:
        lane = self.active.pop(slot)
        self.results[lane.req.uid] = np.asarray(lane.out, np.int32)
        self.stats["evicted"] += 1
        if self.packed:
            self.allocator.free(lane.pages)
            self._table[slot] = paging.trash_page_row(self.max_pages)
            self._push_table()
            if self._trunc[slot]:
                self._trunc[slot] = 0
                self._push_trunc()

    def _push_table(self) -> None:
        l = self.cfg.n_layers
        self.cache["pages"] = jnp.broadcast_to(
            jnp.asarray(self._table)[None], (l,) + self._table.shape)

    def _push_trunc(self) -> None:
        l = self.cfg.n_layers
        self.cache["kv_trunc"] = jnp.broadcast_to(
            jnp.asarray(self._trunc)[None], (l, self.slots))

    # -- the loop ---------------------------------------------------------

    def _last_tokens(self) -> jnp.ndarray:
        tok = np.zeros((self.slots, 1), np.int32)
        for s, lane in self.active.items():
            tok[s, 0] = lane.out[-1]
        return jnp.asarray(tok)

    def _fuse_steps(self) -> int:
        """Largest power-of-two number of decode steps that is safe to run
        without host-side scheduling: no lane reaches ``max_new`` before
        the block ends, and no lane has a stop token (whose firing must be
        observed every step)."""
        if any(l.req.stop_token is not None for l in self.active.values()):
            return 1
        rem = min(l.req.max_new - len(l.out) for l in self.active.values())
        k = 1
        while k * 2 <= min(rem, 32):
            k *= 2
        return k

    def step(self) -> None:
        """One scheduler iteration: admit while pages+slots allow, then a
        fused block of batched decode steps over every lane."""
        self._admit()
        if not self.active:
            return
        k = self._fuse_steps()
        temps, topks, _ = self._lane_params(range(self.slots))
        seeds = np.zeros((k, self.slots), np.int32)
        for s, lane in self.active.items():
            for i in range(k):
                seeds[i, s] = self._seed(lane.req, lane.steps + i)
        toks, self.cache = self._decode_block(
            self.fz, self.tr, self._last_tokens(), self.cache,
            temps, topks, seeds)
        toks = np.asarray(toks)                  # (k, slots)
        self.stats["steps"] += k
        self.stats["occupancy_sum"] += k * len(self.active)
        if self.packed:
            self.stats["page_util_sum"] += k * self.allocator.utilization()
        for i in range(k):
            for s in list(self.active):
                lane = self.active[s]
                tok = int(toks[i, s])
                lane.out.append(tok)
                lane.steps += 1
                if self._done(lane, tok):
                    self._evict(s)

    def run(self) -> Dict[int, np.ndarray]:
        """Drain the queue; returns {uid: generated tokens}. Metrics land
        in ``self.stats`` (occupancy / page-pool utilization are averaged
        by :meth:`summary`)."""
        while self.queue or self.active:
            self.step()
        return self.results

    def summary(self) -> dict:
        steps = max(self.stats["steps"], 1)
        out = {
            "steps": self.stats["steps"],
            "admitted": self.stats["admitted"],
            "evicted": self.stats["evicted"],
            "tokens": int(sum(len(v) for v in self.results.values())),
            "occupancy": self.stats["occupancy_sum"] / (steps * self.slots),
        }
        if self.packed:
            out["page_utilization"] = self.stats["page_util_sum"] / steps
        return out
