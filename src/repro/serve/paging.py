"""Paged packed-KV storage: fixed-size pages of row-planar planes.

The row-planar plane layout (docs/gse-format.md §4) stores one
independently writable word/exponent row per (token, kv-head). This module
carves that S axis into fixed pages: a **page pool** holds ``n_pages``
pages of ``page_size`` rows each — per layer ``kp_words``/``vp_words``
(P, page, Kv, ceil(D/32)·bits) uint32 and ``kp_exp``/``vp_exp``
(P, page, Kv, D/g) int8, stacked to a leading L axis so the decoder scan
carries them — and each sequence's logical KV order is its row of a
``(B, max_pages)`` int32 **page table**: physical page ``table[b, j]``
holds the sequence's rows ``[j·page, (j+1)·page)``.

Two page ids are reserved and never allocated:

* ``NULL_PAGE`` (0) — the permanent zero page. Every row holds the packed
  pattern of a **quantized zero** (offset-binary mantissa fields are
  ``m + 2^(b-1)``, so an all-zero word would dequantize to ``-2^(b-1)``,
  not 0.0 — the pool must be seeded with the real packed-zero pattern:
  under the MSB-first wire format that is an all-ones MSB plane and zero
  lower planes). Active sequences point unallocated logical pages here;
  those columns dequantize to exactly 0.0 and sit behind the per-sequence
  length mask. Zero survives every plane-prefix view: ``2^(b-1) >> t ==
  2^(b'-1)``, the narrower quantized zero — so NULL/TRASH semantics are
  width-independent.
* ``TRASH_PAGE`` (1) — the write sink for inactive batch slots. A freed
  slot keeps riding the batched decode step, and its (stale, still
  advancing) appends must never touch a page that has been recycled to
  another sequence: eviction retargets the slot's whole page-table row at
  the trash page, so every subsequent write lands there.

Allocatable physical pages are ``[FIRST_PAGE, n_pages)``; the host-side
:class:`PageAllocator` hands them out (admission) and takes them back
(eviction) — ``alloc`` returning ``None`` is the admission-backpressure
signal the scheduler waits on.
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.gse import DEFAULT_GROUP
from repro.models.config import ModelConfig

NULL_PAGE = 0
TRASH_PAGE = 1
FIRST_PAGE = 2


class PageAllocator:
    """Host-side free-list allocator over the pool's physical page ids.

    FIFO recycling (freed pages go to the back of the queue) so tests and
    serving runs actually revisit recycled pages instead of ping-ponging
    the same few ids. ``alloc`` is all-or-nothing: a request either gets
    its whole page span or ``None`` (admission backpressure) — no partial
    reservations to leak."""

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < FIRST_PAGE + 1:
            raise ValueError(f"pool needs > {FIRST_PAGE} pages, "
                             f"got {n_pages}")
        self.n_pages = n_pages
        self.page_size = page_size
        self._free = deque(range(FIRST_PAGE, n_pages))
        self._allocated: set = set()

    @property
    def n_allocatable(self) -> int:
        return self.n_pages - FIRST_PAGE

    @property
    def n_free(self) -> int:
        return len(self._free)

    def utilization(self) -> float:
        """Allocated fraction of the allocatable pool — the page-pool
        utilization metric the serving benchmark reports."""
        return len(self._allocated) / max(self.n_allocatable, 1)

    def pages_for(self, n_rows: int) -> int:
        """Pages needed to hold ``n_rows`` KV rows."""
        return -(-n_rows // self.page_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages, or ``None`` if the pool can't cover them."""
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        self._allocated.update(pages)
        return pages

    def free(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p not in self._allocated:
                raise ValueError(f"double free / foreign page {p}")
            self._allocated.discard(p)
            self._free.append(p)


def packed_zero_rows(cfg: ModelConfig, bits: int,
                     group: int = DEFAULT_GROUP):
    """The packed pattern of one quantized-zero KV row: (Kv, W) uint32
    words + (Kv, G) int8 exponents (EXP_MIN). This — not zero words — is
    what every pool page must be seeded with (offset-binary fields)."""
    from repro.kernels.ops import quant_pack_kv_rows
    from repro.serve.engine import _kv_pack_group
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    g = _kv_pack_group(hd, group)
    zw, ze = quant_pack_kv_rows(jnp.zeros((1, 1, kv, hd)), bits, g)
    return zw[0, 0], ze[0, 0]


def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, max_pages: int, bits: int,
                     group: int = DEFAULT_GROUP) -> dict:
    """Zeroed paged decode cache for ``batch`` serving slots.

    Pools (L, P, page, Kv, ·) seeded with the packed-zero pattern on every
    page; page table (L, B, max_pages) — every slot starts inactive, its
    whole row on the trash page; index (L, B) zeros; ``kv_trunc`` (L, B)
    zeros — each slot's extra plane shifts below the read width (a slot
    admitted with ``kv_bits = b`` gets ``stored_bits - b`` here; the
    vector rides the decode step's scalar-prefetch lane, so lanes at
    different widths share one fused block over the one stored-width
    pool). The page table is identical across layers (one allocator feeds
    all layers); it is stacked to (L, ...) purely so the decoder scan can
    carry it per layer.

    ``bits`` is the pool's **stored** width — writes always quantize at
    this width; narrowing happens only at read time (plane-prefix views,
    docs/gse-format.md §7).
    """
    l = cfg.n_layers
    kv = cfg.n_kv_heads
    zw, ze = packed_zero_rows(cfg, bits, group)
    words = jnp.broadcast_to(zw, (l, n_pages, page_size) + zw.shape)
    exps = jnp.broadcast_to(ze, (l, n_pages, page_size) + ze.shape)
    assert words.shape[3] == kv
    return {
        "kp_words": jnp.array(words), "kp_exp": jnp.array(exps),
        "vp_words": jnp.array(words), "vp_exp": jnp.array(exps),
        "pages": jnp.full((l, batch, max_pages), TRASH_PAGE, jnp.int32),
        "index": jnp.zeros((l, batch), jnp.int32),
        "kv_trunc": jnp.zeros((l, batch), jnp.int32),
    }


def slot_page_row(phys_pages: Sequence[int], max_pages: int) -> np.ndarray:
    """Page-table row of an **active** slot: its allocated span, then the
    null page (reads dequantize to 0.0 behind the length mask; active
    slots never write past their span)."""
    row = np.full((max_pages,), NULL_PAGE, np.int32)
    row[:len(phys_pages)] = np.asarray(phys_pages, np.int32)
    return row


def trash_page_row(max_pages: int) -> np.ndarray:
    """Page-table row of an **inactive** slot: everything at the trash
    page, so stale clip-indexed writes land there and nowhere else."""
    return np.full((max_pages,), TRASH_PAGE, np.int32)


def scatter_prefill_pages(cache: dict, planar: dict,
                          phys_pages: Sequence[int]) -> dict:
    """Move one prefilled sequence's planar packed planes into its
    allocated pool pages.

    ``planar``: the ``k_words``/``k_exp``/``v_words``/``v_exp`` leaves of
    :func:`repro.serve.engine.pack_decode_cache_planar` for a batch-1
    temp cache, (L, 1, S, Kv, ·) with ``S >= len(phys_pages) · page``.
    Each allocated page is overwritten **in full** (beyond-prompt rows of
    the temp cache are quantized zeros), so recycled pages never leak a
    previous occupant's rows. Returns the cache with updated pools.
    Traceable: ``phys_pages`` may be a (n,) int array inside jit."""
    page = cache["kp_words"].shape[2]
    ids = jnp.asarray(phys_pages, jnp.int32)
    n = int(ids.shape[0])
    out = dict(cache)
    for pool_key, planar_key in (("kp_words", "k_words"),
                                 ("kp_exp", "k_exp"),
                                 ("vp_words", "v_words"),
                                 ("vp_exp", "v_exp")):
        x = planar[planar_key][:, 0]            # (L, S, Kv, ·)
        l = x.shape[0]
        rows = x[:, :n * page].reshape(l, n, page, *x.shape[2:])
        out[pool_key] = cache[pool_key].at[:, ids].set(rows)
    return out


def page_pool_pspec(mesh, rules, kv_heads: int, n_pages: int):
    """(L, P, page, Kv, ·) partition spec for the pool planes: the
    physical-page axis takes the ``kv_pages`` rule (the data split the
    planar cache put on batch), kv-heads on model when divisible — word
    planes shard exactly like the planar cache's. The page table stays
    replicated (every shard resolves the same logical walk)."""
    from repro.distributed.sharding import resolve_pspec
    model_size = mesh.shape.get("model", 1)
    kv_ax = "kv_heads" if (model_size > 1 and kv_heads % model_size == 0) \
        else None
    return resolve_pspec((1, n_pages, 1, kv_heads, 1),
                         (None, "kv_pages", None, kv_ax, None),
                         mesh, rules)
