"""Serving path: KV/SSM cache management, prefill and single-token decode.

Cache layout (stacked over layers so the decoder stack scans it):
  attention: k/v (L, B, S_max, Kv, D) + index scalar
  ssm:       state (L, B, H, N, P) + conv (L, B, K-1, C)
  encdec:    adds cross k/v (L, B, S_enc, Kv, D)

Cache sharding (DESIGN §5): batch over (pod, data); kv-heads over model when
divisible, otherwise the sequence axis is sharded over model (GQA archs with
few KV heads — the softmax over the sharded length lowers to an all-reduce).

Beyond-paper: ``kv_quant_bits`` stores the KV cache GSE-quantized *and
bit-packed* (the paper's storage format reused as a serving memory
optimization), and — the default decode path — keeps it packed **through
attention**: :func:`pack_decode_cache_planar` converts the prefilled k/v
(and cross k/v) to the row-planar packed planes of
``repro.kernels.flash_attention_packed`` (``*_words`` uint32 bit-planar
mantissas + ``*_exp`` int8 shared exponents, one independently writable
row per (token, kv-head)), and each decode step quantizes+packs only the
new token's rows, writes them in place, and attends fused with tile-local
dequant. The full unpacked cache exists at no point in the decode scan:
peak live KV bytes are the packed planes plus one attention tile
(``docs/benchmarks.md`` shows the measured row).

The legacy round-trip (:func:`pack_decode_cache` /
:func:`unpack_decode_cache`, flat :class:`~repro.core.gse.PackedGSETensor`
leaves at ``b + 5/32`` bits/value) remains for at-rest snapshots — idle
sessions, prefix caches — and as the ``kv_inplace=False`` A/B reference:
re-quantizing an already-GSE-valued cache is exact (same amax -> same
exponent -> same mantissas), so both paths quantize each token exactly
once and agree token-for-token.
"""
from __future__ import annotations

import dataclasses as _dc
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.gse import DEFAULT_GROUP, PackedGSETensor
from repro.core.policy import QuantPolicy
from repro.core.qcd import effective_group_size
from repro.kernels.ops import gse_quantize_pack
from repro.models.config import ModelConfig
from repro.models import model as M
from repro.models import ssm as S
from repro.distributed.sharding import (ShardingRules, current_ctx,
                                        resolve_pspec)

_PACKED_KV_KEYS = ("k", "v", "ck", "cv")


def kv_cache_pspec(mesh, rules: ShardingRules, batch: int, kv_heads: int,
                   seq_len: int = 0):
    """(L, B, S, Kv, D) spec: kv on model when divisible, else the sequence
    axis goes on model (long-context GQA caches). All axes divisibility-
    guarded (e.g. long_500k has batch=1 — batch must replicate)."""
    model_size = mesh.shape.get("model", 1)
    if kv_heads % model_size == 0 and model_size > 1:
        return resolve_pspec((1, batch, max(seq_len, 1), kv_heads, 1),
                             (None, "batch", None, "kv_heads", None),
                             mesh, rules)
    # fall back: shard sequence over model
    seq_rules = _dc.replace(rules, seq="model")
    return resolve_pspec((1, batch, max(seq_len, 1), kv_heads, 1),
                         (None, "batch", "seq", None, None),
                         mesh, seq_rules)


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, enc_len: Optional[int] = None):
    """Zeroed stacked decode cache for ``batch`` sequences of ``max_len``."""
    l = cfg.n_layers
    cache = {}
    if cfg.uses_attention:
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        cache["k"] = jnp.zeros((l, batch, max_len, kv, hd), dtype)
        cache["v"] = jnp.zeros((l, batch, max_len, kv, hd), dtype)
        # per-sequence write index: (L, B) so ragged batches decode with
        # per-row RoPE positions and masks (every row of a static batch
        # just advances in lockstep)
        cache["index"] = jnp.zeros((l, batch), jnp.int32)
    if cfg.uses_ssm:
        sc = S.ssm_cache_init(cfg, batch, l, jnp.float32)
        cache["state"] = sc["state"]
        cache["conv"] = sc["conv"]
    if cfg.is_encoder_decoder:
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        se = enc_len or cfg.encoder_len
        cache["ck"] = jnp.zeros((l, batch, se, kv, hd), dtype)
        cache["cv"] = jnp.zeros((l, batch, se, kv, hd), dtype)
    return cache


def cache_shardings(cfg: ModelConfig, batch: int, max_len: int, mesh, rules,
                    enc_len: Optional[int] = None):
    """NamedSharding tree matching init_decode_cache's structure."""
    out = {}
    if cfg.uses_attention:
        spec = kv_cache_pspec(mesh, rules, batch, cfg.n_kv_heads, max_len)
        out["k"] = NamedSharding(mesh, spec)
        out["v"] = NamedSharding(mesh, spec)
        out["index"] = NamedSharding(mesh, P())
    if cfg.uses_ssm:
        h = cfg.ssm_heads
        out["state"] = NamedSharding(mesh, resolve_pspec(
            (1, batch, h, 1, 1), (None, "batch", "ssm_heads", None, None),
            mesh, rules))
        out["conv"] = NamedSharding(mesh, resolve_pspec(
            (1, batch, 1, 1), (None, "batch", None, None), mesh, rules))
    if cfg.is_encoder_decoder:
        spec = kv_cache_pspec(mesh, rules, batch, cfg.n_kv_heads,
                              enc_len or cfg.encoder_len)
        out["ck"] = NamedSharding(mesh, spec)
        out["cv"] = NamedSharding(mesh, spec)
    return out


def _kv_pack_group(head_dim: int, group: int) -> int:
    """Largest usable group size for quantizing along the head_dim axis:
    the largest divisor of head_dim that is <= group. (The old fallback of
    one shared exponent per whole head — ``group = head_dim`` — silently
    lost precision on non-divisible head_dims.)"""
    return effective_group_size(head_dim, group)


@partial(jax.jit, static_argnames=("bits", "group"))
def pack_decode_cache(cache, bits: int = 8, group: int = DEFAULT_GROUP):
    """GSE-quantize + bit-pack the attention k/v (and cross k/v) leaves.

    Quantization runs along the trailing head_dim axis via the fused
    quantize+pack kernel (``repro.kernels.gse_quant_pack``) — fp values go
    to b-bit words in one pass, no int8 intermediate; ragged head_dims take
    the jnp fallback inside :func:`gse_quantize_pack`. Index, SSM state
    and conv buffers pass through untouched (they are tiny or fp-sensitive).
    Returns a cache dict whose packed leaves are PackedGSETensor pytrees;
    their ``.nbytes`` is the realized b-bit footprint.
    """
    out = dict(cache)
    for key in _PACKED_KV_KEYS:
        if key in cache:
            x = cache[key]
            g = _kv_pack_group(x.shape[-1], group)
            out[key] = gse_quantize_pack(x, bits, g)
    return out


@partial(jax.jit, static_argnames=("dtype",))
def unpack_decode_cache(cache, dtype=jnp.bfloat16):
    """Inverse of :func:`pack_decode_cache` (dequantizes packed leaves)."""
    out = dict(cache)
    for key in _PACKED_KV_KEYS:
        if key in cache and isinstance(cache[key], PackedGSETensor):
            out[key] = cache[key].dequantize(dtype)
    return out


@partial(jax.jit, static_argnames=("bits", "group"))
def pack_decode_cache_planar(cache, bits: int = 8,
                             group: int = DEFAULT_GROUP):
    """Convert the attention k/v (and cross k/v) leaves to **row-planar**
    packed planes — the prefill→packed-decode handoff.

    Each ``key`` leaf (L, B, S, Kv, D) becomes ``key_words``
    (L, B, S, Kv, ceil(D/32)*bits) uint32 and ``key_exp`` (L, B, S, Kv,
    D//g) int8, quantized along head_dim through the fused quantize+pack
    kernel. Unlike :func:`pack_decode_cache` the exponents stay int8 and
    each (token, head) row packs independently, which is what lets
    ``decode_step`` append one token with a single ``dynamic_update_slice``
    and attend without ever unpacking the cache. Index/SSM leaves pass
    through untouched.
    """
    from repro.kernels.ops import quant_pack_kv_rows
    out = {k: v for k, v in cache.items() if k not in _PACKED_KV_KEYS}
    for key in _PACKED_KV_KEYS:
        if key in cache:
            x = cache[key]
            g = _kv_pack_group(x.shape[-1], group)
            words, exps = quant_pack_kv_rows(x, bits, g)
            out[f"{key}_words"] = words
            out[f"{key}_exp"] = exps
    return out


@partial(jax.jit, static_argnames=("head_dim", "dtype"))
def unpack_decode_cache_planar(cache, head_dim: int, dtype=jnp.bfloat16):
    """Inverse of :func:`pack_decode_cache_planar` (tests/inspection only —
    the decode path never materializes this)."""
    from repro.kernels.ops import dequant_kv_rows
    out = {k: v for k, v in cache.items()
           if not k.endswith(("_words", "_exp"))}
    for key in _PACKED_KV_KEYS:
        if f"{key}_words" in cache:
            out[key] = dequant_kv_rows(cache[f"{key}_words"],
                                       cache[f"{key}_exp"], head_dim,
                                       dtype)
    return out


def packed_cache_nbytes(cache) -> int:
    """Realized bytes of the packed k/v storage (the serving memory claim):
    flat PackedGSETensor leaves and/or row-planar word/exponent planes."""
    total = sum(cache[k].nbytes for k in _PACKED_KV_KEYS
                if k in cache and isinstance(cache[k], PackedGSETensor))
    for key in _PACKED_KV_KEYS:
        for suffix in ("_words", "_exp"):
            if f"{key}{suffix}" in cache:
                total += cache[f"{key}{suffix}"].nbytes
    return total


def _split_cache(cache):
    """Partition the flat cache dict into the per-family parts that
    _scan_stack expects per layer (attention keys + ssm keys merged ok)."""
    return cache


def prefill(fz, tr, batch, cache, cfg: ModelConfig, policy: QuantPolicy):
    """Run the prompt through the model, writing the cache. Returns
    (last_logits (B, Vp), cache)."""
    x = M.embed_inputs(fz, batch, cfg)
    if cfg.is_encoder_decoder:
        enc_out = M.encode(fz, tr, batch, cfg, policy)
        # project & store cross k/v per layer, then run decoder with cache
        from repro.models.layers import cross_kv
        ck, cv = jax.vmap(lambda fz_l, tr_l: cross_kv(
            fz_l["cross"], tr_l["cross"], enc_out, cfg, policy))(
                fz["layers"], tr["layers"])
        cache = dict(cache, ck=ck, cv=cv)
        x, cache = M._scan_stack_encdec(fz, tr, x, None, cfg, policy,
                                        positions=None, cache=cache)
    else:
        x, cache = M._scan_stack(fz["layers"], tr["layers"], x, cfg, policy,
                                 positions=None,
                                 use_rope=cfg.family != "encdec",
                                 is_global_flags=_global_flags(cfg),
                                 cache=cache)
    x = M.norm_apply_final(fz, x, cfg)
    logits = M.unembed(fz, x[:, -1:, :], cfg)
    return logits[:, 0], cache


def _global_flags(cfg: ModelConfig):
    if cfg.global_attn_layers:
        return [i in cfg.global_attn_layers for i in range(cfg.n_layers)]
    return None


def decode_step(fz, tr, tokens, cache, cfg: ModelConfig,
                policy: QuantPolicy):
    """One autoregressive step. tokens: (B, 1) int32. Returns
    (logits (B, Vp), new_cache). This is the function the decode_* dry-run
    cells lower."""
    # (L, B) index -> this step's per-sequence (B,) position offsets
    offset = cache["index"][0] if "index" in cache else 0
    x = M.embed_inputs(fz, {"tokens": tokens}, cfg, pos_offset=offset)
    if cfg.is_encoder_decoder:
        x, cache = M._scan_stack_encdec(fz, tr, x, None, cfg, policy,
                                        positions=None, cache=cache)
    else:
        x, cache = M._scan_stack(fz["layers"], tr["layers"], x, cfg, policy,
                                 positions=None,
                                 use_rope=cfg.family != "encdec",
                                 is_global_flags=_global_flags(cfg),
                                 cache=cache)
    x = M.norm_apply_final(fz, x, cfg)
    logits = M.unembed(fz, x, cfg)
    return logits[:, 0], cache


def greedy_generate(fz, tr, prompt, cfg: ModelConfig, policy: QuantPolicy,
                    max_new: int = 16, max_len: Optional[int] = None,
                    kv_quant_bits: Optional[int] = None,
                    kv_group: int = DEFAULT_GROUP,
                    kv_inplace: bool = True,
                    kv_active_bits: Optional[int] = None):
    """Simple batched greedy decoding loop (example/serving driver).

    With ``kv_quant_bits`` set the KV cache lives **bit-packed** for the
    whole decode. Default (``kv_inplace=True``): the scan carry holds the
    row-planar word/exponent planes, each step quantizes+packs only the new
    token's k/v rows, writes them in place, and attends fused over the
    packed cache — the full unpacked cache is never materialized at any
    step. ``kv_inplace=False`` keeps the legacy round-trip (unpack the
    whole cache, attend, re-pack flat PackedGSETensor leaves) as the A/B
    reference. Both paths quantize each token exactly once (re-packing
    GSE-exact values is lossless) and both attend the current token's
    k/v at full precision — the in-place path passes the fresh fp rows
    as an attention tail (quantize-after-attend append) — so they are
    **token-identical at every bit-width** (asserted exactly in
    tests/test_attention_packed.py).

    ``kv_active_bits`` (in-place packed mode only) *stores* the cache at
    ``kv_quant_bits`` but *attends* through the b-bit plane-prefix view
    (docs/gse-format.md §7) — the solo reference for the mixed-``kv_bits``
    continuous-batching lanes, which decode the same narrowed values via
    the per-sequence ``kv_trunc`` shifts.
    """
    b, t = prompt.shape
    if kv_active_bits is not None:
        if kv_quant_bits is None or not kv_inplace:
            raise ValueError("kv_active_bits needs the in-place packed "
                             "cache (kv_quant_bits set, kv_inplace=True)")
        if not 2 <= kv_active_bits <= kv_quant_bits:
            raise ValueError(f"kv_active_bits {kv_active_bits} outside "
                             f"[2, stored {kv_quant_bits}]")
        cfg = _dc.replace(cfg, kv_active_bits=kv_active_bits)
    max_len = max_len or (t + max_new)
    cache = init_decode_cache(cfg, b, max_len)
    logits, cache = prefill(fz, tr, {"tokens": prompt}, cache, cfg, policy)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    packed = kv_quant_bits is not None
    roundtrip = packed and not kv_inplace
    if packed:
        pack = pack_decode_cache_planar if kv_inplace else pack_decode_cache
        cache = pack(cache, kv_quant_bits, kv_group)

    def body(carry, _):
        tok, cache = carry
        if roundtrip:
            # fp32: GSE dequant is exact in fp32, and the appended row must
            # not round through bf16 — the in-place path quantizes and
            # attends the fp row directly, and the A/B identity holds only
            # if this path sees the same values (a bf16 working cache made
            # the two paths quantize *different* current-token values)
            cache = unpack_decode_cache(cache, dtype=jnp.float32)
        logits, cache = decode_step(fz, tr, tok, cache, cfg, policy)
        if roundtrip:
            cache = pack_decode_cache(cache, kv_quant_bits, kv_group)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return (nxt, cache), nxt[:, 0]

    (_, cache), toks = jax.lax.scan(body, (tok, cache), None,
                                    length=max_new - 1)
    return jnp.concatenate([tok, toks.T], axis=1)
