"""Pallas TPU kernel: on-chip unpack of bit-planar packed GSE mantissas.

Input is the real storage format (``repro.core.gse`` module docstring): the
last axis carries ``bits`` MSB-first bit planes of ``ceil(K/32)`` chunks in
*plane-major* word order — word ``p * chunks + c`` is plane ``p`` (holding
mantissa bit ``bits-1-p``) of chunk ``c``; lane ``i`` (bit ``i`` of the
word) is value ``i`` of the chunk. Unpacking is therefore a pure vectorized
shift/mask in VMEM — no gathers, no field ever straddles a word:

    u_i = sum_p ((plane_p >> i) & 1) << (bits-1-p);   m_i = u_i - 2^(bits-1)

The bit-plane loop is a static Python loop of ``bits`` (<= 8) iterations
over rank-3 tiles, which Mosaic maps onto the VPU; interpret mode runs the
identical math on CPU. Masking with ``& 1`` makes the math correct whether
the backend shifts uint32 logically or int32 arithmetically.

Plane-prefix reads (``active_bits < stored bits``): because the layout is
plane-major with the MSB plane first, reading only the first
``active_bits`` planes of each chunk decodes the floor-truncated mantissas
``m >> (stored - active)`` — the kernel's BlockSpec walks a
``(rows, bits, chunks)`` view of the word array and pins the plane axis to
its first ``active_bits`` entries, so narrow reads *move fewer HBM bytes*,
not just mask them after the fact.

HBM holds only the packed words (b bits/value); full int8 mantissas exist
only transiently as VMEM tiles (or as this kernel's output when a consumer
genuinely needs the unpacked working form).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gse import _PACK_CHUNK, unpack_mantissas

DEFAULT_BM = 256
DEFAULT_BK = 512


def unpack_tile(words: jax.Array, bits: int,
                int32_shifts: bool = False) -> jax.Array:
    """(BM, bits*C) uint32 plane-major words -> (BM, C*32) int8 mantissas.

    ``bits`` is the number of planes actually present in ``words`` — a
    plane-prefix tile of a wider stream is decoded by passing its
    ``active_bits``, yielding the floor-truncated mantissas. Shared by this
    kernel, the fused packed matmul, and the packed-KV flash attention. The
    shift/mask body is ``repro.core.gse.unpack_mantissas`` — pure jnp, so
    the same code defines the wire format once and runs both host-side and
    on VMEM-resident tiles inside kernels. ``int32_shifts`` selects the
    bitcast-int32 shift fallback for Mosaic targets lacking u32 shifts
    (bit-identical output, see core.gse).
    """
    k = words.shape[-1] // bits * _PACK_CHUNK
    return unpack_mantissas(words, bits, k, int32_shifts=int32_shifts)


def _gse_unpack_kernel(w_ref, m_ref, *, bits: int, int32_shifts: bool):
    bm = w_ref.shape[0]
    # (bm, bits, ckb) plane-axis block -> the contiguous plane-major tile
    # stream unpack_tile expects
    tile = w_ref[...].reshape(bm, bits * w_ref.shape[2])
    m_ref[...] = unpack_tile(tile, bits, int32_shifts)


@functools.partial(jax.jit,
                   static_argnames=("bits", "active_bits", "bm", "bk",
                                    "interpret", "int32_shifts"))
def gse_unpack_pallas(words: jax.Array, bits: int,
                      active_bits: int | None = None,
                      bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                      interpret: bool = True,
                      int32_shifts: bool = False) -> jax.Array:
    """words (M, bits*(K//32)) uint32 -> mantissas (M, K) int8.

    K is implied by the word count; K % 32 == 0 (kernel storage invariant —
    the jnp path in ``repro.core.gse`` handles ragged tails by padding).
    Tiles (bm, bk) of the *output*; bk % 32 == 0.

    ``active_bits`` (default: ``bits``) decodes the plane-prefix view at a
    narrower width: the index map reads only the first ``active_bits``
    planes of each chunk, so the words of the dropped planes are never
    fetched, and the output is the floor-truncated ``active_bits``-bit
    mantissas.
    """
    ab = bits if active_bits is None else active_bits
    if not 2 <= ab <= bits:
        raise ValueError(f"active_bits {ab} outside [2, bits={bits}]")
    m_dim, kw = words.shape
    k_dim = kw // bits * _PACK_CHUNK
    chunks = k_dim // _PACK_CHUNK
    bm = min(bm, m_dim)
    bk = min(bk, k_dim)
    assert m_dim % bm == 0 and k_dim % bk == 0 and bk % _PACK_CHUNK == 0, (
        words.shape, bits, bm, bk)
    ckb = bk // _PACK_CHUNK
    grid = (m_dim // bm, k_dim // bk)
    kernel = functools.partial(_gse_unpack_kernel, bits=ab,
                               int32_shifts=int32_shifts)
    # (M, bits, chunks) plane-axis view: plane index 0 pins the block to
    # the first `ab` planes — the zero-copy prefix read
    wp = words.reshape(m_dim, bits, chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, ab, ckb), lambda i, j: (i, 0, j))],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, k_dim), jnp.int8),
        interpret=interpret,
    )(wp)
