"""Pallas TPU kernel: on-chip unpack of bit-planar packed GSE mantissas.

Input is the real storage format (``repro.core.gse`` module docstring): the
last axis carries chunks of 32 values as ``bits`` uint32 plane words each —
plane ``j`` holds bit ``j`` of the 32 offset-binary mantissas, lane ``i``
(bit ``i`` of the word) is value ``i`` of the chunk. Unpacking is therefore
a pure vectorized shift/mask in VMEM — no gathers, no field ever straddles
a word:

    u_i = sum_j ((plane_j >> i) & 1) << j;      m_i = u_i - qmax

The bit-plane loop is a static Python loop of ``bits`` (<= 8) iterations
over rank-3 tiles, which Mosaic maps onto the VPU; interpret mode runs the
identical math on CPU. Masking with ``& 1`` makes the math correct whether
the backend shifts uint32 logically or int32 arithmetically.

HBM holds only the packed words (b bits/value); full int8 mantissas exist
only transiently as VMEM tiles (or as this kernel's output when a consumer
genuinely needs the unpacked working form).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gse import _PACK_CHUNK, unpack_mantissas

DEFAULT_BM = 256
DEFAULT_BK = 512


def unpack_tile(words: jax.Array, bits: int,
                int32_shifts: bool = False) -> jax.Array:
    """(BM, C*bits) uint32 plane words -> (BM, C*32) int8 mantissas.

    Shared by this kernel, the fused packed matmul, and the packed-KV flash
    attention. The shift/mask body is ``repro.core.gse.unpack_mantissas`` —
    pure jnp, so the same code defines the wire format once and runs both
    host-side and on VMEM-resident tiles inside kernels.
    ``int32_shifts`` selects the bitcast-int32 shift fallback for Mosaic
    targets lacking u32 shifts (bit-identical output, see core.gse).
    """
    k = words.shape[-1] // bits * _PACK_CHUNK
    return unpack_mantissas(words, bits, k, int32_shifts=int32_shifts)


def _gse_unpack_kernel(w_ref, m_ref, *, bits: int, int32_shifts: bool):
    m_ref[...] = unpack_tile(w_ref[...], bits, int32_shifts)


@functools.partial(jax.jit,
                   static_argnames=("bits", "bm", "bk", "interpret",
                                    "int32_shifts"))
def gse_unpack_pallas(words: jax.Array, bits: int,
                      bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                      interpret: bool = True,
                      int32_shifts: bool = False) -> jax.Array:
    """words (M, K//32*bits) uint32 -> mantissas (M, K) int8.

    K is implied by the word count; K % 32 == 0 (kernel storage invariant —
    the jnp path in ``repro.core.gse`` handles ragged tails by padding).
    Tiles (bm, bk) of the *output*; bk % 32 == 0.
    """
    m_dim, kw = words.shape
    k_dim = kw // bits * _PACK_CHUNK
    bm = min(bm, m_dim)
    bk = min(bk, k_dim)
    assert m_dim % bm == 0 and k_dim % bk == 0 and bk % _PACK_CHUNK == 0, (
        words.shape, bits, bm, bk)
    bkw = bk // _PACK_CHUNK * bits
    grid = (m_dim // bm, k_dim // bk)
    kernel = functools.partial(_gse_unpack_kernel, bits=bits,
                               int32_shifts=int32_shifts)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bkw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, k_dim), jnp.int8),
        interpret=interpret,
    )(words)
