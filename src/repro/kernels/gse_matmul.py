"""Pallas TPU kernel: GSE integer matmul — the paper's core compute path
(Sec. 2.2 "Matrix Multiplication using GSE") mapped onto the MXU.

    y[m, n] = sum_g  2^(eA[m,g] + eB[n,g]) * sum_i mA[m,g,i] * mB[n,g,i]

TPU mapping (DESIGN §4): the inner integer MAC runs as an int8 x int8 ->
int32 ``dot_general`` with the group axis as a batch dimension (the MXU
executes contraction-G batched matmuls); the per-(m, n, g) rescale
``2^(eA+eB)`` is a rank-1 outer product applied to each group's int32 tile
while it lives in VMEM, accumulated into an fp32 scratch tile across the K
grid. This is bit-exact w.r.t. the value-space oracle
(``repro.core.gse.gse_matmul_reference``) because int32 accumulates the
group MAC exactly and fp32 holds each scaled group product.

A (M, K) x B (N, K) -> (M, N); both operands pre-quantized to GSE along K.

Two entry points share the MAC body:

* :func:`gse_matmul_pallas` — both mantissa operands as int8 arrays (the
  working form).
* :func:`gse_matmul_packed_pallas` — the **fused packed-dequant matmul**:
  the B (weight) mantissas arrive as bit-planar packed uint32 words (the
  real storage format, ``repro.core.gse`` docstring) and are unpacked by
  shift/mask *inside* the kernel while the tile sits in VMEM. Weights
  therefore never materialize as int8 in HBM — HBM traffic for B is
  b bits/value, the paper's memory claim on the compute path.

Backward-pass variants (packed residuals, paper Sec. 2.3)
----------------------------------------------------------

The QCD training path saves its backward residuals Q(X)/Q(W) as packed
word streams; the two backward GEMMs contract over an axis that is *not*
the grouping axis of one (dX) or either (dW) operand, so the rank-1
integer rescale of the forward kernel does not apply. Both kernels
instead dequantize each packed tile **in VMEM** (shift/mask unpack +
exact power-of-two rescale — every dequantized value is exact in fp32)
and run an fp32 MAC, accumulating contraction tiles sequentially in
ascending order (the ordered-accumulation contract; oracles in
``repro.kernels.ref`` replay the identical tile sequence, so parity is
bit-exact, not allclose). HBM traffic for both operands stays at
b bits/value — the unpacked residual never exists outside VMEM.

* :func:`gse_matmul_packed_nt_pallas` — dX = Q(dY) @ Q(W)^T: A (M, N)
  packed along the contraction axis N, B (N, K) packed along its *last*
  axis K while the contraction runs over its leading axis (the
  "transposed-contraction" access pattern).
* :func:`gse_matmul_packed_tn_pallas` — dW = Q(X)^T @ Q(dY): both
  operands packed along their last (output) axes, contraction over the
  shared leading token axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gse import (_PACK_CHUNK, exp2_int, mantissa_abs_max,
                            qmax_for_bits)
from repro.kernels.gse_unpack import unpack_tile

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512

# Static overflow guard for the realigned int-MAC mode: the int32
# accumulator of one contraction tile must hold depth * |m_a| * |m_b| in
# the worst case (every realigned mantissa at full scale). Module-level so
# tests can shrink it to exercise the guard without a 2^18-deep GEMM.
INT32_ACC_MAX = 2 ** 31 - 1


def int_mac_max_depth(a_bits: int, b_bits: int,
                      a_truncated: bool = False,
                      b_truncated: bool = False) -> int:
    """Largest contraction-tile depth whose realigned int32 accumulation
    cannot wrap: depth * |m_a|_max * |m_b|_max <= INT32_ACC_MAX.

    Plane-prefix views floor-truncate and can decode ``-2^(b-1)`` — one
    past ``qmax`` — so the ``*_truncated`` flags budget the asymmetric
    bound (``mantissa_abs_max``) and the safe depth shrinks slightly for
    truncated operands.
    """
    return INT32_ACC_MAX // (mantissa_abs_max(a_bits, a_truncated)
                             * mantissa_abs_max(b_bits, b_truncated))


def check_int_mac_depth(depth: int, a_bits: int, b_bits: int,
                        a_truncated: bool = False,
                        b_truncated: bool = False) -> None:
    """Reject (at trace time) a tile configuration whose realigned int-MAC
    accumulation could overflow int32. ``depth`` is the contraction extent
    of ONE kernel tile (the int32 accumulator is rescaled to fp32 at every
    tile boundary, so only the in-tile depth counts). Truncated (plane-
    prefix view) operands use the widened ``qmax+1`` magnitude bound."""
    limit = int_mac_max_depth(a_bits, b_bits, a_truncated, b_truncated)
    if depth > limit:
        raise ValueError(
            f"int-MAC tile depth {depth} can overflow int32 accumulation at "
            f"{a_bits}x{b_bits} bits"
            f"{' (truncated operands)' if a_truncated or b_truncated else ''}"
            f" (max safe depth {limit}); shrink the "
            "contraction tile or disable int_mac")


def gse_group_products(am, ae, bm, be, *, group: int):
    """The shared-exponent integer MAC of one tile, group-batched: int8
    mantissas am (BM, BK) x bm (BN, BK) with per-group exponents
    ae (BM, BK/G) / be (BN, BK/G) -> fp32 scaled products (ng, BM, BN).

    int8 x int8 -> int32 ``dot_general`` with the group axis batched (the
    MXU form), then the rank-1 ``2^(eA+eB)`` rescale. Every scaled term is
    exact in fp32: the group MAC is an integer < 2^24 and ``exp2_int``
    builds the power of two exactly (XLA exp2 can be an ulp off)."""
    bm_sz, bk = am.shape
    bn_sz = bm.shape[0]
    ng = bk // group

    # (G-batched) integer MAC on the MXU: (ng, BM, G) x (ng, BN, G) -> int32
    ag = am.reshape(bm_sz, ng, group).transpose(1, 0, 2)
    bg = bm.reshape(bn_sz, ng, group).transpose(1, 0, 2)
    prod = jax.lax.dot_general(
        ag, bg, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32)             # (ng, BM, BN)

    sa = exp2_int(ae).transpose(1, 0)                 # (ng, BM)
    sb = exp2_int(be).transpose(1, 0)                 # (ng, BN)
    return prod.astype(jnp.float32) * sa[:, :, None] * sb[:, None, :]


def _mac_accumulate(am, ae, bm, be, acc_ref, *, group: int):
    """One K-tile of the GSE MAC: int8 group-batched dot on the MXU, then
    the rank-1 ``2^(eA+eB)`` rescale, accumulated into fp32 ``acc_ref``.

    Groups are accumulated **sequentially in ascending order** (static
    unrolled loop) — the ordered-accumulation contract of
    ``gse_matmul_reference``; the K grid walks tiles in ascending order, so
    the global fp32 add sequence matches the oracle exactly and parity is
    bit-exact, not just allclose."""
    scaled = gse_group_products(am, ae, bm, be, group=group)
    acc = acc_ref[...]
    for gi in range(scaled.shape[0]):  # ordered fp32 accumulation (contract)
        acc = acc + scaled[gi]
    acc_ref[...] = acc


def gse_score_tile(qm, qe, km, ke, *, group: int):
    """Integer-MAC attention score tile: q mantissas (R, D) int8 with
    exponents (R, D/G) x k mantissas (S, D) / (S, D/G) -> scores (R, S)
    fp32, **before** the softmax scale.

    head_dim D is the row-planar grouping axis, so the forward matmul
    kernel's exact recipe applies verbatim: per-group int8 MXU MAC, rank-1
    ``2^(eq+ek)`` rescale, groups summed in ascending order from zero (the
    ordered-accumulation contract — equal to the grouped fp32 oracle
    ``ref.gse_score_int_ref`` bit-for-bit, since every within-group partial
    sum shares one power-of-two scale and fits 24 mantissa bits)."""
    scaled = gse_group_products(qm, qe, km, ke, group=group)
    acc = jnp.zeros(scaled.shape[1:], jnp.float32)
    for gi in range(scaled.shape[0]):
        acc = acc + scaled[gi]
    return acc


def realign_rows(m, e, *, group: int):
    """Realign GSE mantissas of each ROW onto that row's max exponent:
    m (R, C) int8 grouped along C (e (R, C/G) int8) -> (m' int8 (R, C),
    e_max (R,) int32) with m' = m >> (e_max - e) (arithmetic shift = floor
    division by the power of two — low bits shift out; this is the lossy
    half of the bounded-tier contract)."""
    e32 = e.astype(jnp.int32)
    e_max = jnp.max(e32, axis=-1)                     # (R,)
    s = e_max[:, None] - e32                          # (R, C/G)
    r, c = m.shape
    mg = m.astype(jnp.int32).reshape(r, c // group, group)
    mg = jax.lax.shift_right_arithmetic(
        mg, jnp.broadcast_to(s[..., None], mg.shape))
    return mg.reshape(r, c).astype(jnp.int8), e_max


def realign_col_groups(m, e, *, group: int):
    """Realign each COLUMN GROUP of GSE mantissas onto the group's max
    exponent across all rows: m (R, C) int8 grouped along C (e (R, C/G)
    int8) -> (m' int8 (R, C), e_max (C/G,) int32). Used when the
    contraction runs over the rows, so each output column needs one shared
    scale across every contracted row."""
    e32 = e.astype(jnp.int32)
    e_max = jnp.max(e32, axis=0)                      # (C/G,)
    s = e_max[None, :] - e32                          # (R, C/G)
    r, c = m.shape
    mg = m.astype(jnp.int32).reshape(r, c // group, group)
    mg = jax.lax.shift_right_arithmetic(
        mg, jnp.broadcast_to(s[..., None], mg.shape))
    return mg.reshape(r, c).astype(jnp.int8), e_max


def _gse_matmul_kernel(am_ref, ae_ref, bm_ref, be_ref, o_ref, acc_ref, *,
                       group: int, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _mac_accumulate(am_ref[...], ae_ref[...], bm_ref[...], be_ref[...],
                    acc_ref, group=group)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...]


def _gse_matmul_packed_kernel(am_ref, ae_ref, bw_ref, be_ref, o_ref,
                              acc_ref, *, bits: int, group: int,
                              k_steps: int, int32_shifts: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # bw_ref is the (bn, bits, ckb) plane-axis block — only the active
    # planes were fetched; flatten back to the plane-major tile stream
    bw = bw_ref[...].reshape(bw_ref.shape[0], bits * bw_ref.shape[2])
    bm = unpack_tile(bw, bits, int32_shifts)           # VMEM-only int8 tile
    _mac_accumulate(am_ref[...], ae_ref[...], bm, be_ref[...],
                    acc_ref, group=group)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("group", "bm", "bn", "bk", "interpret"))
def gse_matmul_pallas(a_m, a_e, b_m, b_e, group: int = 32,
                      bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                      bk: int = DEFAULT_BK, interpret: bool = True):
    """a_m (M, K) int8, a_e (M, K//G) int8; b_m (N, K) int8, b_e likewise.
    Returns (M, N) fp32."""
    m_dim, k_dim = a_m.shape
    n_dim = b_m.shape[0]
    bm = min(bm, m_dim)
    bn = min(bn, n_dim)
    bk = min(bk, k_dim)
    assert m_dim % bm == 0 and n_dim % bn == 0 and k_dim % bk == 0
    assert bk % group == 0
    k_steps = k_dim // bk
    grid = (m_dim // bm, n_dim // bn, k_steps)
    kernel = functools.partial(_gse_matmul_kernel, group=group,
                               k_steps=k_steps)
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk // group), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, bk // group), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_m, a_e, b_m, b_e)


def _shift_exponents(e, shift: int):
    """Fold a plane-prefix view's exponent compensation into the working
    int8 exponents (``e + (stored - active)``; max 15 + 6 fits int8)."""
    if not shift:
        return e
    return (e.astype(jnp.int32) + shift).astype(jnp.int8)


@functools.partial(jax.jit,
                   static_argnames=("bits", "group", "bm", "bn", "bk",
                                    "interpret", "int32_shifts",
                                    "active_bits"))
def gse_matmul_packed_pallas(a_m, a_e, b_words, b_e, bits: int,
                             group: int = 32,
                             bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                             bk: int = DEFAULT_BK, interpret: bool = True,
                             int32_shifts: bool = False,
                             active_bits: int | None = None):
    """Fused packed-dequant GSE matmul.

    a_m (M, K) int8, a_e (M, K//G) int8 — activations in working form;
    b_words (N, bits*(K//32)) uint32 — weight mantissas in packed storage
    (``bits`` = stored width = word stride); b_e (N, K//G) int8. Returns
    (M, N) fp32, bit-exact vs the unpacked kernel and
    ``gse_matmul_reference``.

    ``active_bits`` (default ``bits``) reads the plane-prefix view: the
    word BlockSpec walks the (N, bits, K//32) plane-axis view and fetches
    only the first ``active_bits`` planes per K tile — the dropped planes'
    HBM bytes are never moved — while the exponent compensation
    ``bits - active_bits`` folds into ``b_e`` before the call.
    """
    ab = bits if active_bits is None else active_bits
    if not 2 <= ab <= bits:
        raise ValueError(f"active_bits {ab} outside [2, bits={bits}]")
    m_dim, k_dim = a_m.shape
    n_dim = b_words.shape[0]
    assert b_words.shape[1] * _PACK_CHUNK == k_dim * bits, (
        "packed word count mismatch", b_words.shape, k_dim, bits)
    b_e = _shift_exponents(b_e, bits - ab)
    bm = min(bm, m_dim)
    bn = min(bn, n_dim)
    bk = min(bk, k_dim)
    assert m_dim % bm == 0 and n_dim % bn == 0 and k_dim % bk == 0
    assert bk % group == 0 and bk % _PACK_CHUNK == 0
    chunks = k_dim // _PACK_CHUNK
    ckb = bk // _PACK_CHUNK
    k_steps = k_dim // bk
    grid = (m_dim // bm, n_dim // bn, k_steps)
    kernel = functools.partial(_gse_matmul_packed_kernel, bits=ab,
                               group=group, k_steps=k_steps,
                               int32_shifts=int32_shifts)
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bk // group), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, ab, ckb), lambda i, j, k: (j, 0, k)),
            pl.BlockSpec((bn, bk // group), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, n_dim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a_m, a_e, b_words.reshape(n_dim, bits, chunks), b_e)


# ---------------------------------------------------------------------------
# Packed backward-residual matmuls (tile-local dequant, fp32 ordered MAC).
# ---------------------------------------------------------------------------

def dequant_packed_tile(words, e, bits: int, group: int,
                        int32_shifts: bool = False):
    """One VMEM tile: packed words (R, bits*(C//32)) uint32 plane-major +
    shared exponents (R, C//group) int8 -> exactly-dequantized fp32 (R, C).

    Shared by both backward kernels and the ref oracles: shift/mask unpack
    (``unpack_tile``) then the exact ``exp2_int`` power-of-two rescale —
    each value ``m * 2^e`` is exact in fp32 (|m| <= 128; the power-of-two
    extreme a truncated plane-prefix tile can decode to is exact too)."""
    m = unpack_tile(words, bits, int32_shifts)            # (R, C) int8
    r, c = m.shape
    mg = m.astype(jnp.float32).reshape(r, c // group, group)
    scale = exp2_int(e)                                   # (R, C//group) f32
    return (mg * scale[:, :, None]).reshape(r, c)


def _gse_matmul_packed_nt_kernel(aw_ref, ae_ref, bw_ref, be_ref, o_ref,
                                 acc_ref, *, a_bits: int, b_bits: int,
                                 a_group: int, b_group: int, n_steps: int,
                                 int32_shifts: bool, int_mac: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    aw = aw_ref[...].reshape(aw_ref.shape[0], a_bits * aw_ref.shape[2])
    bw = bw_ref[...].reshape(bw_ref.shape[0], b_bits * bw_ref.shape[2])
    if int_mac:
        # bounded tier: realign both tiles onto tile-shared exponents (A
        # per row — its grouping axis IS the contraction; B per K column
        # group — its contraction runs over rows), int8 MXU MAC in int32,
        # one rank-1 2^(eamax+ebmax) rescale per tile. Low mantissa bits
        # shift out in the realignment: NOT bit-exact vs the fp32 tier
        # (error bound: ref.int_realign_bound).
        am = unpack_tile(aw, a_bits, int32_shifts)            # (bm, bn)
        bm = unpack_tile(bw, b_bits, int32_shifts)            # (bn, bk)
        am_r, ea_max = realign_rows(am, ae_ref[...], group=a_group)
        bm_r, eb_max = realign_col_groups(bm, be_ref[...], group=b_group)
        prod = jax.lax.dot_general(
            am_r, bm_r, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)                 # (bm, bk)
        sa = exp2_int(ea_max)                                 # (bm,)
        sb = exp2_int(eb_max)                                 # (bk/G,)
        bm_sz, bk = prod.shape
        scaled = prod.astype(jnp.float32) * sa[:, None]
        scaled = (scaled.reshape(bm_sz, bk // b_group, b_group)
                  * sb[None, :, None]).reshape(bm_sz, bk)
        acc_ref[...] = acc_ref[...] + scaled
    else:
        adeq = dequant_packed_tile(aw, ae_ref[...], a_bits, a_group,
                                   int32_shifts)              # (bm, bn)
        bdeq = dequant_packed_tile(bw, be_ref[...], b_bits, b_group,
                                   int32_shifts)              # (bn, bk)
        acc_ref[...] = acc_ref[...] + jnp.dot(
            adeq, bdeq, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("a_bits", "b_bits", "a_group", "b_group",
                                    "bm", "bn", "bk", "interpret",
                                    "int32_shifts", "int_mac",
                                    "a_active_bits", "b_active_bits",
                                    "a_truncated", "b_truncated"))
def gse_matmul_packed_nt_pallas(a_words, a_e, b_words, b_e, a_bits: int,
                                b_bits: int, a_group: int = 32,
                                b_group: int = 32,
                                bm: int = DEFAULT_BM, bn: int = DEFAULT_BK,
                                bk: int = DEFAULT_BN, interpret: bool = True,
                                int32_shifts: bool = False,
                                int_mac: bool = False,
                                a_active_bits: int | None = None,
                                b_active_bits: int | None = None,
                                a_truncated: bool = False,
                                b_truncated: bool = False):
    """dX-shaped packed matmul: A (M, N) @ B (N, K) -> (M, K) fp32,
    contracting over N.

    a_words (M, N//32*a_bits) uint32 — A mantissas packed along N (the
    contraction axis; for dX this is Q(dY), grouped along N per the paper);
    a_e (M, N//a_group) int8. b_words (N, K//32*b_bits) uint32 — B packed
    along its last axis K (the saved Q(W)^T residual, forward-grouped along
    K); b_e (N, K//b_group) int8 (the two operands' grouping axes differ,
    hence separate group sizes). ``bn`` tiles the contraction axis: per grid step
    both tiles are dequantized in VMEM and fp32-MAC'd, tiles accumulated in
    ascending N order (the ordered-accumulation contract —
    ``ref.gse_matmul_packed_nt_ref`` replays the same sequence).

    ``int_mac=True`` swaps the tile MAC for the realigned integer path
    (bounded tier): mantissas shift onto a tile-shared exponent in VMEM,
    the MAC runs int8 x int8 -> int32 on the MXU and one ``exp2_int``
    rescale closes the tile. Not bit-exact (realignment drops low bits;
    oracle ``ref.gse_matmul_packed_nt_int_ref``, bound
    ``ref.int_realign_bound``); :func:`check_int_mac_depth` rejects tile
    depths whose int32 accumulation could wrap.

    ``a_active_bits`` / ``b_active_bits`` (default: the stored widths) read
    either operand as its plane-prefix view: only the active planes are
    fetched per tile, exponent compensation folds into the working
    exponents, and the int-MAC depth guard widens to the truncated
    ``qmax+1`` magnitude bound.
    """
    a_ab = a_bits if a_active_bits is None else a_active_bits
    b_ab = b_bits if b_active_bits is None else b_active_bits
    if not (2 <= a_ab <= a_bits and 2 <= b_ab <= b_bits):
        raise ValueError(f"active bits ({a_ab}, {b_ab}) outside "
                         f"[2, stored ({a_bits}, {b_bits})]")
    a_e = _shift_exponents(a_e, a_bits - a_ab)
    b_e = _shift_exponents(b_e, b_bits - b_ab)
    m_dim, naw = a_words.shape
    n_dim, nbw = b_words.shape
    assert naw * _PACK_CHUNK == n_dim * a_bits, (a_words.shape, n_dim, a_bits)
    k_dim = nbw // b_bits * _PACK_CHUNK
    bm = min(bm, m_dim)
    bn = min(bn, n_dim)
    bk = min(bk, k_dim)
    assert m_dim % bm == 0 and n_dim % bn == 0 and k_dim % bk == 0, (
        (m_dim, n_dim, k_dim), (bm, bn, bk))
    assert bn % a_group == 0 and bn % _PACK_CHUNK == 0
    assert bk % b_group == 0 and bk % _PACK_CHUNK == 0
    bnc = bn // _PACK_CHUNK
    bkc = bk // _PACK_CHUNK
    if int_mac:
        # an operand is truncated if this call narrows it (active < stored)
        # OR the caller already holds a pre-narrowed plane-prefix view and
        # says so (a_truncated/b_truncated — e.g. PackedGSETensor.with_bits
        # words arriving at their face width)
        check_int_mac_depth(bn, a_ab, b_ab,
                            a_truncated=a_truncated or a_ab < a_bits,
                            b_truncated=b_truncated or b_ab < b_bits)
    n_steps = n_dim // bn
    grid = (m_dim // bm, k_dim // bk, n_steps)
    kernel = functools.partial(_gse_matmul_packed_nt_kernel, a_bits=a_ab,
                               b_bits=b_ab, a_group=a_group,
                               b_group=b_group, n_steps=n_steps,
                               int32_shifts=int32_shifts, int_mac=int_mac)
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, a_ab, bnc), lambda i, j, n: (i, 0, n)),
            pl.BlockSpec((bm, bn // a_group), lambda i, j, n: (i, n)),
            pl.BlockSpec((bn, b_ab, bkc), lambda i, j, n: (n, 0, j)),
            pl.BlockSpec((bn, bk // b_group), lambda i, j, n: (n, j)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, n: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, k_dim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(a_words.reshape(m_dim, a_bits, naw // a_bits), a_e,
      b_words.reshape(n_dim, b_bits, nbw // b_bits), b_e)


def _gse_matmul_packed_tn_kernel(aw_ref, ae_ref, bw_ref, be_ref, o_ref,
                                 acc_ref, *, a_bits: int, b_bits: int,
                                 a_group: int, b_group: int, m_steps: int,
                                 int32_shifts: bool, int_mac: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    aw = aw_ref[...].reshape(aw_ref.shape[0], a_bits * aw_ref.shape[2])
    bw = bw_ref[...].reshape(bw_ref.shape[0], b_bits * bw_ref.shape[2])
    if int_mac:
        # bounded tier: the contraction runs over the shared leading axis
        # of BOTH operands, so both realign per output column group (one
        # shared exponent per group across all contracted rows), then one
        # dim0 x dim0 int8 MXU MAC and a rank-1 rescale per tile.
        am = unpack_tile(aw, a_bits, int32_shifts)            # (bm, bk)
        bm = unpack_tile(bw, b_bits, int32_shifts)            # (bm, bn)
        am_r, ea_max = realign_col_groups(am, ae_ref[...], group=a_group)
        bm_r, eb_max = realign_col_groups(bm, be_ref[...], group=b_group)
        prod = jax.lax.dot_general(
            am_r, bm_r, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)                 # (bk, bn)
        sa = exp2_int(ea_max)                                 # (bk/Ga,)
        sb = exp2_int(eb_max)                                 # (bn/Gb,)
        bk, bn_sz = prod.shape
        scaled = (prod.astype(jnp.float32).reshape(
            bk // a_group, a_group, bn_sz) * sa[:, None, None]
        ).reshape(bk, bn_sz)
        scaled = (scaled.reshape(bk, bn_sz // b_group, b_group)
                  * sb[None, :, None]).reshape(bk, bn_sz)
        acc_ref[...] = acc_ref[...] + scaled
    else:
        adeq = dequant_packed_tile(aw, ae_ref[...], a_bits, a_group,
                                   int32_shifts)              # (bm, bk)
        bdeq = dequant_packed_tile(bw, be_ref[...], b_bits, b_group,
                                   int32_shifts)              # (bm, bn)
        acc_ref[...] = acc_ref[...] + jax.lax.dot_general(
            adeq, bdeq, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bk, bn)

    @pl.when(pl.program_id(2) == m_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("a_bits", "b_bits", "a_group", "b_group",
                                    "bm", "bn", "bk", "interpret",
                                    "int32_shifts", "int_mac",
                                    "a_active_bits", "b_active_bits",
                                    "a_truncated", "b_truncated"))
def gse_matmul_packed_tn_pallas(a_words, a_e, b_words, b_e, a_bits: int,
                                b_bits: int, a_group: int = 32,
                                b_group: int = 32,
                                bm: int = DEFAULT_BK, bn: int = DEFAULT_BN,
                                bk: int = DEFAULT_BM, interpret: bool = True,
                                int32_shifts: bool = False,
                                int_mac: bool = False,
                                a_active_bits: int | None = None,
                                b_active_bits: int | None = None,
                                a_truncated: bool = False,
                                b_truncated: bool = False):
    """dW-shaped packed matmul: A (M, K)^T @ B (M, N) -> (K, N) fp32,
    contracting over the shared leading token axis M of both packed
    operands (for dW: A is the saved Q(X) residual grouped along K, B the
    freshly packed Q(dY) grouped along N).

    a_words (M, K//32*a_bits), a_e (M, K//a_group); b_words
    (M, N//32*b_bits), b_e (M, N//b_group). ``bm`` tiles the contraction axis; tiles are
    dequantized in VMEM, fp32-MAC'd with a dim-0 x dim-0 ``dot_general``,
    and accumulated in ascending M order (ordered-accumulation contract).

    ``int_mac=True``: realigned integer tile MAC (bounded tier — see
    :func:`gse_matmul_packed_nt_pallas`; oracle
    ``ref.gse_matmul_packed_tn_int_ref``).

    ``a_active_bits`` / ``b_active_bits``: plane-prefix reads of either
    operand, exactly as in :func:`gse_matmul_packed_nt_pallas`.
    """
    a_ab = a_bits if a_active_bits is None else a_active_bits
    b_ab = b_bits if b_active_bits is None else b_active_bits
    if not (2 <= a_ab <= a_bits and 2 <= b_ab <= b_bits):
        raise ValueError(f"active bits ({a_ab}, {b_ab}) outside "
                         f"[2, stored ({a_bits}, {b_bits})]")
    a_e = _shift_exponents(a_e, a_bits - a_ab)
    b_e = _shift_exponents(b_e, b_bits - b_ab)
    m_dim, naw = a_words.shape
    m2, nbw = b_words.shape
    assert m_dim == m2, (a_words.shape, b_words.shape)
    k_dim = naw // a_bits * _PACK_CHUNK
    n_dim = nbw // b_bits * _PACK_CHUNK
    bm = min(bm, m_dim)
    bn = min(bn, n_dim)
    bk = min(bk, k_dim)
    assert m_dim % bm == 0 and n_dim % bn == 0 and k_dim % bk == 0, (
        (m_dim, n_dim, k_dim), (bm, bn, bk))
    assert bk % a_group == 0 and bk % _PACK_CHUNK == 0
    assert bn % b_group == 0 and bn % _PACK_CHUNK == 0
    bkc = bk // _PACK_CHUNK
    bnc = bn // _PACK_CHUNK
    if int_mac:
        check_int_mac_depth(bm, a_ab, b_ab,
                            a_truncated=a_truncated or a_ab < a_bits,
                            b_truncated=b_truncated or b_ab < b_bits)
    m_steps = m_dim // bm
    grid = (k_dim // bk, n_dim // bn, m_steps)
    kernel = functools.partial(_gse_matmul_packed_tn_kernel, a_bits=a_ab,
                               b_bits=b_ab, a_group=a_group,
                               b_group=b_group, m_steps=m_steps,
                               int32_shifts=int32_shifts, int_mac=int_mac)
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, a_ab, bkc), lambda i, j, s: (s, 0, i)),
            pl.BlockSpec((bm, bk // a_group), lambda i, j, s: (s, i)),
            pl.BlockSpec((bm, b_ab, bnc), lambda i, j, s: (s, 0, j)),
            pl.BlockSpec((bm, bn // b_group), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k_dim, n_dim), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32)],
        interpret=interpret,
    )(a_words.reshape(m_dim, a_bits, naw // a_bits), a_e,
      b_words.reshape(m_dim, b_bits, nbw // b_bits), b_e)
