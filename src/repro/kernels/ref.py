"""Pure-jnp oracles for every Pallas kernel in this package. Tests sweep
shapes/dtypes and assert_allclose kernel-vs-oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gse import (EXP_MIN, EXP_MAX, as_f32_exact, ceil_log2,
                            exp2_int, mantissa_abs_max, plane_prefix_words,
                            qmax_for_bits, unpack_mantissas)
from repro.core.nf4 import NF4_CODE, BLOCK


def gse_quantize_ref(x: jax.Array, bits: int = 6, group: int = 32):
    """(M, K) -> (mantissa int8, exponent int8 (M, K//G)). Mirrors
    repro.core.gse.gse_quantize but returns raw arrays (kernel ABI)."""
    m_dim, k_dim = x.shape
    qmax = qmax_for_bits(bits)
    xf = as_f32_exact(x).reshape(m_dim, k_dim // group, group)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    safe = jnp.where(amax > 0, amax, 1.0)
    e = ceil_log2(safe / qmax)
    e = jnp.where(amax > 0, e, EXP_MIN)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    m = jnp.clip(jnp.round(xf / exp2_int(e)[..., None]), -qmax, qmax)
    return (m.reshape(m_dim, k_dim).astype(jnp.int8), e.astype(jnp.int8))


def gse_matmul_ref(a_m, a_e, b_m, b_e, group: int = 32):
    """Oracle for gse_matmul_pallas: exact per-group int MAC + 2^(eA+eB),
    fp32-accumulated sequentially in ascending group order (the ordered-
    accumulation contract shared with gse_matmul_reference and the
    kernels — see repro.core.gse.gse_matmul_reference)."""
    m_dim, k_dim = a_m.shape
    n_dim = b_m.shape[0]
    ng = k_dim // group
    ag = a_m.reshape(m_dim, ng, group).astype(jnp.int32)
    bg = b_m.reshape(n_dim, ng, group).astype(jnp.int32)
    prod = jnp.einsum("mgk,ngk->mng", ag, bg)
    scale = exp2_int(a_e)[:, None, :] * exp2_int(b_e)[None, :, :]
    terms = prod.astype(jnp.float32) * scale
    acc = jnp.zeros((m_dim, n_dim), jnp.float32)
    for gi in range(ng):
        acc = acc + terms[:, :, gi]
    return acc


def gse_quant_pack_ref(x: jax.Array, bits: int = 6, group: int = 32):
    """Oracle for gse_quant_pack_pallas: quantize-then-pack as two separate
    dispatches (the pre-fusion path, int8 intermediate materialized).
    Returns (mantissa words uint32 (M, K//32*bits), exponent int8 (M, K//G))
    — must be bit-identical to the fused kernel for every bits in [2, 8]."""
    from repro.core.gse import pack_mantissas
    m, e = gse_quantize_ref(x, bits, group)
    return pack_mantissas(m, bits), e


def gse_unpack_ref(words, bits: int, active_bits: int | None = None):
    """Oracle for gse_unpack_pallas: (M, K//32*bits) uint32 -> (M, K) int8
    via the jnp bit-plane unpack in repro.core.gse. ``active_bits`` decodes
    the plane-prefix view (floor-truncated mantissas) from the same full-
    width words, mirroring the kernel's narrow read."""
    ab = bits if active_bits is None else active_bits
    m_dim, kw = words.shape
    k_dim = kw // bits * 32
    return unpack_mantissas(plane_prefix_words(words, bits, ab), ab, k_dim)


def gse_matmul_packed_ref(a_m, a_e, b_words, b_e, bits: int,
                          group: int = 32, active_bits: int | None = None):
    """Oracle for gse_matmul_packed_pallas: unpack then exact GSE matmul.
    ``active_bits`` replays the plane-prefix read: truncated mantissas with
    the B exponents compensated by ``bits - active_bits``."""
    ab = bits if active_bits is None else active_bits
    b_m = gse_unpack_ref(b_words, bits, ab)
    if ab != bits:
        b_e = (b_e.astype(jnp.int32) + (bits - ab)).astype(jnp.int8)
    return gse_matmul_ref(a_m, a_e, b_m, b_e, group)


def _dequant_rows_ref(words, e, bits: int, group: int,
                      active_bits: int | None = None):
    """Unpack + exact dequant of a whole packed operand: (R, C//32*bits)
    uint32 + (R, C//G) int8 -> fp32 (R, C). Same math as the kernels'
    ``dequant_packed_tile`` but via the host-side ``unpack_mantissas``.
    ``active_bits`` dequantizes the plane-prefix view (truncated mantissas
    scaled by ``2^(e + bits - active_bits)``)."""
    ab = bits if active_bits is None else active_bits
    c = words.shape[-1] // bits * 32
    m = unpack_mantissas(plane_prefix_words(words, bits, ab), ab,
                         c).astype(jnp.float32)
    mg = m.reshape(*m.shape[:-1], c // group, group)
    scale = exp2_int(e.astype(jnp.int32) + (bits - ab))
    return (mg * scale[..., None]).reshape(m.shape)


def gse_matmul_packed_nt_ref(a_words, a_e, b_words, b_e, a_bits: int,
                             b_bits: int, group: int = 32, bn: int = 512,
                             a_active_bits: int | None = None,
                             b_active_bits: int | None = None):
    """Oracle for gse_matmul_packed_nt_pallas: dequantize both packed
    operands exactly in fp32 and replay the kernel's contraction schedule —
    one fp32 dot per ``bn``-wide N tile, tiles accumulated sequentially in
    ascending order (the ordered-accumulation contract; bit-exact vs the
    kernel at the same ``bn``)."""
    m_dim = a_words.shape[0]
    n_dim = b_words.shape[0]
    k_dim = b_words.shape[-1] // b_bits * 32
    adeq = _dequant_rows_ref(a_words, a_e, a_bits, group,
                             a_active_bits)                 # (M, N)
    bdeq = _dequant_rows_ref(b_words, b_e, b_bits, group,
                             b_active_bits)                 # (N, K)
    bn = min(bn, n_dim)
    acc = jnp.zeros((m_dim, k_dim), jnp.float32)
    for n0 in range(0, n_dim, bn):
        acc = acc + jnp.dot(adeq[:, n0:n0 + bn], bdeq[n0:n0 + bn, :],
                            preferred_element_type=jnp.float32)
    return acc


def gse_matmul_packed_tn_ref(a_words, a_e, b_words, b_e, a_bits: int,
                             b_bits: int, group: int = 32, bm: int = 512,
                             a_active_bits: int | None = None,
                             b_active_bits: int | None = None):
    """Oracle for gse_matmul_packed_tn_pallas: exact fp32 dequant of both
    packed operands, then the dim-0 x dim-0 contraction replayed one
    ``bm``-wide M tile at a time in ascending order."""
    m_dim = a_words.shape[0]
    k_dim = a_words.shape[-1] // a_bits * 32
    n_dim = b_words.shape[-1] // b_bits * 32
    adeq = _dequant_rows_ref(a_words, a_e, a_bits, group,
                             a_active_bits)                 # (M, K)
    bdeq = _dequant_rows_ref(b_words, b_e, b_bits, group,
                             b_active_bits)                 # (M, N)
    bm = min(bm, m_dim)
    acc = jnp.zeros((k_dim, n_dim), jnp.float32)
    for m0 in range(0, m_dim, bm):
        acc = acc + jax.lax.dot_general(
            adeq[m0:m0 + bm], bdeq[m0:m0 + bm], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return acc


# ---------------------------------------------------------------------------
# Integer-MAC oracles (exact tier: grouped fp32 score GEMM; bounded tier:
# floor-division realignment replay + worst-case error bound).
# ---------------------------------------------------------------------------


def gse_score_int_ref(q, k_words, k_exp, head_dim: int,
                      active_bits: int | None = None):
    """Grouped fp32 oracle for the integer-MAC attention score GEMM
    (``gse_matmul.gse_score_tile`` fed by in-kernel q quantization).

    q (R, D) float; k planes (S, W) uint32 + (S, D/G) int8. Quantizes q to
    the cache's bits/group with the reference quantizer, dequantizes both
    operands EXACTLY to fp32 (k via the independent numpy wire decode),
    then runs one fp32 GEMM PER GROUP, summed in ascending group order.
    Every within-group partial sum is exact in fp32 — all products share
    the scale ``2^(eq+ek)`` and their integer content stays below 2^24 —
    so this float computation equals the int32 MAC + rank-1 rescale
    **bit-for-bit** (the exact-tier contract). ``active_bits`` replays a
    plane-prefix read of the cache: k decodes truncated (exponents
    compensated) and q quantizes at the active width, matching the
    kernel's in-kernel q quantization. Returns (R, S) pre-scale scores."""
    chunks = -(-head_dim // 32)
    bits = k_words.shape[-1] // chunks
    ab = bits if active_bits is None else active_bits
    g = head_dim // k_exp.shape[-1]
    ng = head_dim // g
    qm, qe = gse_quantize_ref(jnp.asarray(q, jnp.float32), ab, g)
    qdq = (qm.astype(jnp.float32).reshape(-1, ng, g)
           * exp2_int(qe.astype(jnp.int32))[..., None])       # (R, ng, g)
    kdq = packed_kv_dequant_ref(k_words, k_exp, head_dim, ab)
    kdq = kdq.reshape(-1, ng, g)                              # (S, ng, g)
    acc = jnp.zeros((qdq.shape[0], kdq.shape[0]), jnp.float32)
    for gi in range(ng):                  # ordered group sum (contract)
        acc = acc + jnp.dot(qdq[:, gi], kdq[:, gi].T,
                            preferred_element_type=jnp.float32)
    return acc


def _realign_rows_ref(m, e, group: int):
    """Floor-division formulation of the kernel's row realignment (the
    kernel shifts; floor(m / 2^s) == m >> s for every sign) — deliberately
    NOT sharing the shift helper so a shift bug cannot cancel out."""
    e32 = e.astype(jnp.int32)
    e_max = jnp.max(e32, axis=-1)
    s = e_max[:, None] - e32
    r, c = m.shape
    mg = m.astype(jnp.float32).reshape(r, c // group, group)
    mg = jnp.floor(mg * exp2_int(-s)[..., None])   # exact: |m| < 2^7
    return mg.astype(jnp.int32).reshape(r, c), e_max


def _realign_col_groups_ref(m, e, group: int):
    """Column-group variant: one shared exponent per group of C across all
    rows (max over the contracted rows)."""
    e32 = e.astype(jnp.int32)
    e_max = jnp.max(e32, axis=0)
    s = e_max[None, :] - e32
    r, c = m.shape
    mg = m.astype(jnp.float32).reshape(r, c // group, group)
    mg = jnp.floor(mg * exp2_int(-s)[..., None])
    return mg.astype(jnp.int32).reshape(r, c), e_max


def gse_matmul_packed_nt_int_ref(a_words, a_e, b_words, b_e, a_bits: int,
                                 b_bits: int, a_group: int = 32,
                                 b_group: int = 32, bn: int = 512,
                                 a_active_bits: int | None = None,
                                 b_active_bits: int | None = None):
    """Oracle for ``gse_matmul_packed_nt_pallas(int_mac=True)``: replay the
    tile schedule with the floor-division realignment, an exact integer
    tile GEMM, and the per-tile rank-1 rescale, tiles accumulated in
    ascending order — bit-exact vs the int-MAC kernel at the same ``bn``
    (every rescale multiplies by a power of two, hence is exact). Active
    bits replay the plane-prefix read: truncated mantissas with exponents
    compensated before realignment."""
    a_ab = a_bits if a_active_bits is None else a_active_bits
    b_ab = b_bits if b_active_bits is None else b_active_bits
    m_dim = a_words.shape[0]
    n_dim = b_words.shape[0]
    k_dim = b_words.shape[-1] // b_bits * 32
    ma = unpack_mantissas(plane_prefix_words(a_words, a_bits, a_ab), a_ab,
                          n_dim)
    mb = unpack_mantissas(plane_prefix_words(b_words, b_bits, b_ab), b_ab,
                          k_dim)
    a_e = (a_e.astype(jnp.int32) + (a_bits - a_ab)).astype(jnp.int8)
    b_e = (b_e.astype(jnp.int32) + (b_bits - b_ab)).astype(jnp.int8)
    bn = min(bn, n_dim)
    acc = jnp.zeros((m_dim, k_dim), jnp.float32)
    for n0 in range(0, n_dim, bn):
        am_r, eam = _realign_rows_ref(
            ma[:, n0:n0 + bn],
            a_e[:, n0 // a_group:(n0 + bn) // a_group], a_group)
        bm_r, ebm = _realign_col_groups_ref(
            mb[n0:n0 + bn], b_e[n0:n0 + bn], b_group)
        prod = jnp.dot(am_r, bm_r)                        # exact int32
        scaled = prod.astype(jnp.float32) * exp2_int(eam)[:, None]
        scaled = (scaled.reshape(m_dim, k_dim // b_group, b_group)
                  * exp2_int(ebm)[None, :, None]).reshape(m_dim, k_dim)
        acc = acc + scaled
    return acc


def gse_matmul_packed_tn_int_ref(a_words, a_e, b_words, b_e, a_bits: int,
                                 b_bits: int, a_group: int = 32,
                                 b_group: int = 32, bm: int = 512,
                                 a_active_bits: int | None = None,
                                 b_active_bits: int | None = None):
    """Oracle for ``gse_matmul_packed_tn_pallas(int_mac=True)``: both
    operands realign per output column group (contraction runs over the
    shared leading axis), exact integer tile GEMM, rank-1 rescale, ordered
    tile accumulation."""
    a_ab = a_bits if a_active_bits is None else a_active_bits
    b_ab = b_bits if b_active_bits is None else b_active_bits
    m_dim = a_words.shape[0]
    k_dim = a_words.shape[-1] // a_bits * 32
    n_dim = b_words.shape[-1] // b_bits * 32
    ma = unpack_mantissas(plane_prefix_words(a_words, a_bits, a_ab), a_ab,
                          k_dim)
    mb = unpack_mantissas(plane_prefix_words(b_words, b_bits, b_ab), b_ab,
                          n_dim)
    a_e = (a_e.astype(jnp.int32) + (a_bits - a_ab)).astype(jnp.int8)
    b_e = (b_e.astype(jnp.int32) + (b_bits - b_ab)).astype(jnp.int8)
    bm = min(bm, m_dim)
    acc = jnp.zeros((k_dim, n_dim), jnp.float32)
    for m0 in range(0, m_dim, bm):
        am_r, eam = _realign_col_groups_ref(
            ma[m0:m0 + bm], a_e[m0:m0 + bm], a_group)
        bm_r, ebm = _realign_col_groups_ref(
            mb[m0:m0 + bm], b_e[m0:m0 + bm], b_group)
        prod = jax.lax.dot_general(am_r, bm_r, (((0,), (0,)), ((), ())))
        scaled = (prod.astype(jnp.float32).reshape(
            k_dim // a_group, a_group, n_dim)
            * exp2_int(eam)[:, None, None]).reshape(k_dim, n_dim)
        scaled = (scaled.reshape(k_dim, n_dim // b_group, b_group)
                  * exp2_int(ebm)[None, :, None]).reshape(k_dim, n_dim)
        acc = acc + scaled
    return acc


def int_realign_bound(a_e, b_e, a_bits: int, b_bits: int, *,
                      a_group: int = 32, b_group: int = 32,
                      tile: int = 512, kind: str = "nt",
                      a_truncated: bool = False,
                      b_truncated: bool = False):
    """Worst-case |int-MAC − fp32 kernel| bound per output element for the
    realigned (bounded-tier) matmuls — the documented contract the
    property tests assert.

    Realignment drops the bits shifted out of each mantissa: the value
    error per operand entry is < ``2^e_max`` (one ulp of the tile-shared
    scale). A depth-``n`` tile contraction therefore errs by at most
    ``n * 2^(ea_max + eb_max) * (qmax_a + qmax_b)`` per element (cross
    terms: |da|*|b| + |a'|*|db|), plus the fp32 rounding slack of the
    fp32 kernel's own tile GEMM (``n * qmax_a * qmax_b * 2^-20`` covers
    the 2^-24 fp32 ulp with 16x headroom). Tiles sum.

    ``kind="nt"``: a_e (M, N/Ga), b_e (N, K/Gb) -> bound (M, K).
    ``kind="tn"``: a_e (M, K/Ga), b_e (M, N/Gb) -> bound (K, N).

    ``a_truncated``/``b_truncated``: the operand is a plane-prefix view,
    whose mantissas reach ``-2^(bits-1)`` (one past qmax) — pass the
    *active* bits as ``a_bits``/``b_bits`` and set the flag, and note the
    caller's exponents must already carry the view's compensation shift.
    """
    qa = mantissa_abs_max(a_bits, a_truncated)
    qb = mantissa_abs_max(b_bits, b_truncated)
    slack = (qa + qb) + tile * qa * qb * 2.0 ** -20
    ae = jnp.asarray(a_e, jnp.int32)
    be = jnp.asarray(b_e, jnp.int32)
    if kind == "nt":
        m_dim, nga = ae.shape
        n_dim = nga * a_group
        bound = jnp.zeros((m_dim, be.shape[-1] * b_group), jnp.float32)
        for n0 in range(0, n_dim, tile):
            depth = min(tile, n_dim - n0)
            eam = jnp.max(ae[:, n0 // a_group:(n0 + depth) // a_group],
                          axis=-1)                           # (M,)
            ebm = jnp.max(be[n0:n0 + depth], axis=0)         # (K/Gb,)
            sc = exp2_int(eam)[:, None] * jnp.repeat(
                exp2_int(ebm), b_group)[None, :]
            bound = bound + depth * slack * sc
        return bound
    if kind == "tn":
        m_dim = ae.shape[0]
        bound = jnp.zeros((ae.shape[-1] * a_group,
                           be.shape[-1] * b_group), jnp.float32)
        for m0 in range(0, m_dim, tile):
            depth = min(tile, m_dim - m0)
            eam = jnp.max(ae[m0:m0 + depth], axis=0)         # (K/Ga,)
            ebm = jnp.max(be[m0:m0 + depth], axis=0)         # (N/Gb,)
            sc = (jnp.repeat(exp2_int(eam), a_group)[:, None]
                  * jnp.repeat(exp2_int(ebm), b_group)[None, :])
            bound = bound + depth * slack * sc
        return bound
    raise ValueError(f"unknown kind {kind!r}")


def nf4_dequant_ref(codes, absmax, out_dtype=jnp.bfloat16):
    """Oracle for nf4_dequant_pallas."""
    m_dim, k_dim = codes.shape
    code = jnp.asarray(NF4_CODE)
    vals = code[codes.astype(jnp.int32)]
    vals = vals.reshape(m_dim, k_dim // BLOCK, BLOCK)
    scales = absmax.reshape(m_dim, k_dim // BLOCK)
    return (vals * scales[..., None]).reshape(m_dim, k_dim).astype(out_dtype)


def flash_attention_oracle(q, k, v, causal=True, window=0, q_offset=0):
    """Materialized-scores oracle for the flash-attention kernel path."""
    from repro.models.attention import direct_attention, MaskInfo
    return direct_attention(q, k, v,
                            MaskInfo(q_offset=q_offset, causal=causal,
                                     window=window))


def plane_prefix_truncate_ref(m, e, stored_bits: int, b: int):
    """Floor-truncation oracle for ``PackedGSETensor.with_bits(b)``: the
    value a ``b``-bit plane-prefix read of a ``stored_bits``-bit stream
    must decode to. Deliberately computed as numpy floor *division* (not a
    shift) so a shift-direction bug in the wire code cannot cancel out.

    m int8 mantissas, e int8 exponents (grouped shape) -> (m_t int32 in
    [-2^(b-1), 2^(b-1)-1], e_t int32 = e + (stored_bits - b))."""
    import numpy as np
    t = stored_bits - b
    m_t = np.floor_divide(np.asarray(m, np.int64), 1 << t)
    return m_t.astype(np.int32), np.asarray(e, np.int32) + t


def packed_kv_dequant_ref(words, exps, head_dim: int,
                          active_bits: int | None = None):
    """Oracle for the row-planar KV dequant: numpy bit-field decode written
    straight from the wire spec (docs/gse-format.md §3.1/§4/§7),
    deliberately NOT sharing ``unpack_mantissas`` so a layout bug in the
    shared helper cannot cancel out in the parity test. (..., W) uint32 +
    (..., G) int8 -> (..., head_dim) fp32 (each product mantissa*2^e is
    fp32-exact).

    ``active_bits``: decode the plane-prefix view — read only the first
    ``active_bits`` planes of each row and scale by ``2^(e + shift)``."""
    import numpy as np
    w = np.asarray(words, np.uint32)
    e = np.asarray(exps, np.int64)
    d32 = -(-head_dim // 32) * 32
    chunks = d32 // 32
    bits = w.shape[-1] // chunks
    ab = bits if active_bits is None else active_bits
    wf = w.reshape(-1, bits, chunks)
    # value i of a row: bit-plane p (holding mantissa bit bits-1-p, MSB
    # plane first) lives at bit (i % 32) of word p * chunks + (i // 32);
    # fields are offset-binary (m + 2^(bits-1)); the prefix view keeps
    # planes [0, ab) and compensates the exponents by (bits - ab)
    idx = np.arange(head_dim)
    chunk, lane = idx // 32, idx % 32
    u = np.zeros((wf.shape[0], head_dim), np.int64)
    for p in range(ab):
        u |= ((wf[:, p, chunk] >> lane) & 1).astype(np.int64) << (ab - 1 - p)
    m = (u - (1 << (ab - 1))).reshape(*w.shape[:-1], head_dim)
    g = head_dim // e.shape[-1]
    scale = np.exp2(e.astype(np.float64) + (bits - ab))  # exact powers of 2
    vals = m.astype(np.float32).reshape(*m.shape[:-1], e.shape[-1], g)
    out = vals * scale[..., None].astype(np.float32)
    return jnp.asarray(out.reshape(*m.shape[:-1], head_dim), jnp.float32)


def flash_attention_packed_oracle(q, k_words, k_exp, v_words, v_exp,
                                  causal=True, window=0, q_offset=0,
                                  bq=256, bk=512, kv_active_bits=None):
    """Unpack-then-attend oracle for the packed-KV flash kernel: dequantize
    the **entire** K/V (what the round-trip decode path used to do), then
    run the dense flash kernel at the identical tiling. Because GSE dequant
    is exact in fp32 and both kernels share ``online_softmax_update``/
    ``tile_position_mask``, the fused kernel must match this **bit-exactly**
    (the ordered-accumulation contract), not just allclose."""
    from repro.kernels.flash_attention import flash_attention_pallas
    d = q.shape[-1]
    # kv_active_bits replays a plane-prefix read of the KV rows (floor-
    # truncated mantissas, compensated exponents)
    k = packed_kv_dequant_ref(k_words, k_exp, d, kv_active_bits)
    v = packed_kv_dequant_ref(v_words, v_exp, d, kv_active_bits)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, bq=bq, bk=bk,
                                  interpret=True)


def flash_attention_packed_gqa_oracle(q, k_words, k_exp, v_words, v_exp,
                                      causal=True, window=0, q_offset=0,
                                      bq=256, bk=512, kv_active_bits=None):
    """Expand-then-attend oracle for the GQA grid: replicate every packed
    K/V plane row ``G = H // Kv`` times (exactly the memory expansion the
    GQA grid exists to avoid) and run the MHA oracle head-by-head. The GQA
    kernel — which dequantizes each plane row once per kv-head while the q
    block walks its group — must match this **bit-exactly**.

    q (B, T, H, D); planes (B, S, Kv, ·) -> (B, T, H, D)."""
    b, t, h, d = q.shape
    s, kv = k_words.shape[1], k_words.shape[2]
    g = h // kv

    def expand(x):                    # (B, S, Kv, ·) -> (B*Kv*G, S, ·)
        return jnp.repeat(x.transpose(0, 2, 1, 3), g, axis=1).reshape(
            b * h, s, -1)
    qm = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    o = flash_attention_packed_oracle(
        qm, expand(k_words), expand(k_exp), expand(v_words), expand(v_exp),
        causal=causal, window=window, q_offset=q_offset, bq=bq, bk=bk,
        kv_active_bits=kv_active_bits)
    return o.reshape(b, h, t, d).transpose(0, 2, 1, 3)


def flash_attention_paged_oracle(q, k_words, k_exp, v_words, v_exp,
                                 page_table, causal=True, window=0,
                                 q_offset=0, bq=256, kv_active_bits=None):
    """Gather-then-attend oracle for the paged kernel: resolve each
    sequence's page-table row with a plain numpy index (straight from the
    §4 wire spec — physical page ``pt[b, j]`` holds logical rows
    ``[j*page, (j+1)*page)``), stitch the logical planar view, and replay
    the **non-paged** GQA oracle per sequence with that row's scalar
    offset. The paged kernel — which never materializes the gather — must
    match this bit-exactly.

    q (B, T, H, D); pools (P, page, Kv, ·); page_table (B, maxp) int32.

    ``kv_active_bits``: an int (every sequence reads the same width) or a
    per-sequence (B,) vector of active plane counts — the oracle for the
    mixed-precision decode lanes of the serving engine."""
    import numpy as np
    b = q.shape[0]
    page = k_words.shape[1]
    pt = np.asarray(page_table)
    off = np.broadcast_to(np.asarray(q_offset), (b,))
    if kv_active_bits is None:
        ab = [None] * b
    else:
        ab = [int(x) for x in np.broadcast_to(np.asarray(kv_active_bits),
                                              (b,))]
    outs = []
    for i in range(b):
        def view(pool):           # (P, page, Kv, ·) -> (1, maxp*page, Kv, ·)
            g = np.asarray(pool)[pt[i]]
            return jnp.asarray(g.reshape(1, -1, *pool.shape[2:]))
        outs.append(flash_attention_packed_gqa_oracle(
            q[i:i + 1], view(k_words), view(k_exp), view(v_words),
            view(v_exp), causal=causal, window=window,
            q_offset=int(off[i]), bq=bq, bk=page, kv_active_bits=ab[i]))
    return jnp.concatenate(outs, axis=0)
