"""Pure-jnp oracles for every Pallas kernel in this package. Tests sweep
shapes/dtypes and assert_allclose kernel-vs-oracle."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.gse import (EXP_MIN, EXP_MAX, as_f32_exact, ceil_log2,
                            exp2_int, qmax_for_bits, unpack_mantissas)
from repro.core.nf4 import NF4_CODE, BLOCK


def gse_quantize_ref(x: jax.Array, bits: int = 6, group: int = 32):
    """(M, K) -> (mantissa int8, exponent int8 (M, K//G)). Mirrors
    repro.core.gse.gse_quantize but returns raw arrays (kernel ABI)."""
    m_dim, k_dim = x.shape
    qmax = qmax_for_bits(bits)
    xf = as_f32_exact(x).reshape(m_dim, k_dim // group, group)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    safe = jnp.where(amax > 0, amax, 1.0)
    e = ceil_log2(safe / qmax)
    e = jnp.where(amax > 0, e, EXP_MIN)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    m = jnp.clip(jnp.round(xf / exp2_int(e)[..., None]), -qmax, qmax)
    return (m.reshape(m_dim, k_dim).astype(jnp.int8), e.astype(jnp.int8))


def gse_matmul_ref(a_m, a_e, b_m, b_e, group: int = 32):
    """Oracle for gse_matmul_pallas: exact per-group int MAC + 2^(eA+eB),
    fp32-accumulated sequentially in ascending group order (the ordered-
    accumulation contract shared with gse_matmul_reference and the
    kernels — see repro.core.gse.gse_matmul_reference)."""
    m_dim, k_dim = a_m.shape
    n_dim = b_m.shape[0]
    ng = k_dim // group
    ag = a_m.reshape(m_dim, ng, group).astype(jnp.int32)
    bg = b_m.reshape(n_dim, ng, group).astype(jnp.int32)
    prod = jnp.einsum("mgk,ngk->mng", ag, bg)
    scale = exp2_int(a_e)[:, None, :] * exp2_int(b_e)[None, :, :]
    terms = prod.astype(jnp.float32) * scale
    acc = jnp.zeros((m_dim, n_dim), jnp.float32)
    for gi in range(ng):
        acc = acc + terms[:, :, gi]
    return acc


def gse_quant_pack_ref(x: jax.Array, bits: int = 6, group: int = 32):
    """Oracle for gse_quant_pack_pallas: quantize-then-pack as two separate
    dispatches (the pre-fusion path, int8 intermediate materialized).
    Returns (mantissa words uint32 (M, K//32*bits), exponent int8 (M, K//G))
    — must be bit-identical to the fused kernel for every bits in [2, 8]."""
    from repro.core.gse import pack_mantissas
    m, e = gse_quantize_ref(x, bits, group)
    return pack_mantissas(m, bits), e


def gse_unpack_ref(words, bits: int):
    """Oracle for gse_unpack_pallas: (M, K//32*bits) uint32 -> (M, K) int8
    via the jnp bit-plane unpack in repro.core.gse."""
    m_dim, kw = words.shape
    k_dim = kw // bits * 32
    return unpack_mantissas(words, bits, k_dim)


def gse_matmul_packed_ref(a_m, a_e, b_words, b_e, bits: int,
                          group: int = 32):
    """Oracle for gse_matmul_packed_pallas: unpack then exact GSE matmul."""
    b_m = gse_unpack_ref(b_words, bits)
    return gse_matmul_ref(a_m, a_e, b_m, b_e, group)


def _dequant_rows_ref(words, e, bits: int, group: int):
    """Unpack + exact dequant of a whole packed operand: (R, C//32*bits)
    uint32 + (R, C//G) int8 -> fp32 (R, C). Same math as the kernels'
    ``dequant_packed_tile`` but via the host-side ``unpack_mantissas``."""
    c = words.shape[-1] // bits * 32
    m = unpack_mantissas(words, bits, c).astype(jnp.float32)
    mg = m.reshape(*m.shape[:-1], c // group, group)
    return (mg * exp2_int(e)[..., None]).reshape(m.shape)


def gse_matmul_packed_nt_ref(a_words, a_e, b_words, b_e, a_bits: int,
                             b_bits: int, group: int = 32, bn: int = 512):
    """Oracle for gse_matmul_packed_nt_pallas: dequantize both packed
    operands exactly in fp32 and replay the kernel's contraction schedule —
    one fp32 dot per ``bn``-wide N tile, tiles accumulated sequentially in
    ascending order (the ordered-accumulation contract; bit-exact vs the
    kernel at the same ``bn``)."""
    m_dim = a_words.shape[0]
    n_dim = b_words.shape[0]
    k_dim = b_words.shape[-1] // b_bits * 32
    adeq = _dequant_rows_ref(a_words, a_e, a_bits, group)   # (M, N)
    bdeq = _dequant_rows_ref(b_words, b_e, b_bits, group)   # (N, K)
    bn = min(bn, n_dim)
    acc = jnp.zeros((m_dim, k_dim), jnp.float32)
    for n0 in range(0, n_dim, bn):
        acc = acc + jnp.dot(adeq[:, n0:n0 + bn], bdeq[n0:n0 + bn, :],
                            preferred_element_type=jnp.float32)
    return acc


def gse_matmul_packed_tn_ref(a_words, a_e, b_words, b_e, a_bits: int,
                             b_bits: int, group: int = 32, bm: int = 512):
    """Oracle for gse_matmul_packed_tn_pallas: exact fp32 dequant of both
    packed operands, then the dim-0 x dim-0 contraction replayed one
    ``bm``-wide M tile at a time in ascending order."""
    m_dim = a_words.shape[0]
    k_dim = a_words.shape[-1] // a_bits * 32
    n_dim = b_words.shape[-1] // b_bits * 32
    adeq = _dequant_rows_ref(a_words, a_e, a_bits, group)   # (M, K)
    bdeq = _dequant_rows_ref(b_words, b_e, b_bits, group)   # (M, N)
    bm = min(bm, m_dim)
    acc = jnp.zeros((k_dim, n_dim), jnp.float32)
    for m0 in range(0, m_dim, bm):
        acc = acc + jax.lax.dot_general(
            adeq[m0:m0 + bm], bdeq[m0:m0 + bm], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    return acc


def nf4_dequant_ref(codes, absmax, out_dtype=jnp.bfloat16):
    """Oracle for nf4_dequant_pallas."""
    m_dim, k_dim = codes.shape
    code = jnp.asarray(NF4_CODE)
    vals = code[codes.astype(jnp.int32)]
    vals = vals.reshape(m_dim, k_dim // BLOCK, BLOCK)
    scales = absmax.reshape(m_dim, k_dim // BLOCK)
    return (vals * scales[..., None]).reshape(m_dim, k_dim).astype(out_dtype)


def flash_attention_oracle(q, k, v, causal=True, window=0, q_offset=0):
    """Materialized-scores oracle for the flash-attention kernel path."""
    from repro.models.attention import direct_attention, MaskInfo
    return direct_attention(q, k, v,
                            MaskInfo(q_offset=q_offset, causal=causal,
                                     window=window))


def packed_kv_dequant_ref(words, exps, head_dim: int):
    """Oracle for the row-planar KV dequant: numpy bit-field decode written
    straight from the wire spec (docs/gse-format.md §3.1/§4), deliberately
    NOT sharing ``unpack_mantissas`` so a layout bug in the shared helper
    cannot cancel out in the parity test. (..., W) uint32 + (..., G) int8
    -> (..., head_dim) fp32 (each product mantissa*2^e is fp32-exact)."""
    import numpy as np
    w = np.asarray(words, np.uint32)
    e = np.asarray(exps, np.int64)
    d32 = -(-head_dim // 32) * 32
    chunks = d32 // 32
    bits = w.shape[-1] // chunks
    qmax = 2 ** (bits - 1) - 1
    wf = w.reshape(-1, chunks, bits)
    # value i of a row: bit-plane p lives at bit (i % 32) of word
    # (i // 32) * bits + p; fields are offset-binary (m + qmax)
    idx = np.arange(head_dim)
    chunk, lane = idx // 32, idx % 32
    u = np.zeros((wf.shape[0], head_dim), np.int64)
    for p in range(bits):
        u |= ((wf[:, chunk, p] >> lane) & 1).astype(np.int64) << p
    m = (u - qmax).reshape(*w.shape[:-1], head_dim)
    g = head_dim // e.shape[-1]
    scale = np.exp2(e.astype(np.float64))            # exact powers of two
    vals = m.astype(np.float32).reshape(*m.shape[:-1], e.shape[-1], g)
    out = vals * scale[..., None].astype(np.float32)
    return jnp.asarray(out.reshape(*m.shape[:-1], head_dim), jnp.float32)


def flash_attention_packed_oracle(q, k_words, k_exp, v_words, v_exp,
                                  causal=True, window=0, q_offset=0,
                                  bq=256, bk=512):
    """Unpack-then-attend oracle for the packed-KV flash kernel: dequantize
    the **entire** K/V (what the round-trip decode path used to do), then
    run the dense flash kernel at the identical tiling. Because GSE dequant
    is exact in fp32 and both kernels share ``online_softmax_update``/
    ``tile_position_mask``, the fused kernel must match this **bit-exactly**
    (the ordered-accumulation contract), not just allclose."""
    from repro.kernels.flash_attention import flash_attention_pallas
    d = q.shape[-1]
    k = packed_kv_dequant_ref(k_words, k_exp, d)
    v = packed_kv_dequant_ref(v_words, v_exp, d)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, bq=bq, bk=bk,
                                  interpret=True)


def flash_attention_packed_gqa_oracle(q, k_words, k_exp, v_words, v_exp,
                                      causal=True, window=0, q_offset=0,
                                      bq=256, bk=512):
    """Expand-then-attend oracle for the GQA grid: replicate every packed
    K/V plane row ``G = H // Kv`` times (exactly the memory expansion the
    GQA grid exists to avoid) and run the MHA oracle head-by-head. The GQA
    kernel — which dequantizes each plane row once per kv-head while the q
    block walks its group — must match this **bit-exactly**.

    q (B, T, H, D); planes (B, S, Kv, ·) -> (B, T, H, D)."""
    b, t, h, d = q.shape
    s, kv = k_words.shape[1], k_words.shape[2]
    g = h // kv

    def expand(x):                    # (B, S, Kv, ·) -> (B*Kv*G, S, ·)
        return jnp.repeat(x.transpose(0, 2, 1, 3), g, axis=1).reshape(
            b * h, s, -1)
    qm = q.transpose(0, 2, 1, 3).reshape(b * h, t, d)
    o = flash_attention_packed_oracle(
        qm, expand(k_words), expand(k_exp), expand(v_words), expand(v_exp),
        causal=causal, window=window, q_offset=q_offset, bq=bq, bk=bk)
    return o.reshape(b, h, t, d).transpose(0, 2, 1, 3)
