"""Pallas TPU kernel: **fused** GSE quantize + bit-planar pack.

Previously the storage path was two dispatches — ``gse_quantize`` (find the
shared group exponent, shift mantissas, write int8) followed by ``gse_pack``
(bit-planar uint32 packing) — with the full int8 mantissa tensor living in
HBM between them. This kernel computes group amax → shared exponent →
mantissa → offset-binary bit planes in a single VMEM pass, so the int8
working form never touches HBM: a tile goes fp32-in / b-bit-words-out.

Outputs per (BM, BK) input tile:

* mantissa words  (BM, bits * BK//32) uint32 — the wire layout of
  ``repro.core.gse`` (plane-major MSB-first bit planes over chunks of 32,
  offset-binary ``m + 2^(bits-1)``), identical word-for-word to
  ``gse_pack(gse_quantize(x))``.
* exponents       (BM, BK//G) int8 — unbiased shared exponents. Exponents
  are ~``1/group`` of the payload and their wire layout is a *flat* stream
  over the whole tensor (chunk boundaries cross kernel tiles), so the 5-bit
  exponent packing stays a host-side jnp epilogue
  (:func:`repro.core.gse.pack_exponents`) on the kernel's int8 output.

The quantize math is literally ``repro.kernels.gse_quant.quantize_tile``
(the shared tile body of the non-fused kernel) and the pack math is
literally ``repro.core.gse.pack_mantissas`` running on the VMEM-resident
tile — one definition of each half, host and kernel, so the two kernels
cannot silently diverge on the bit-exact parity contract.

:func:`gse_quantize_pack` is the shape-polymorphic convenience used by the
optimizer / KV-cache / checkpoint hot paths: it returns a
:class:`~repro.core.gse.PackedGSETensor` and falls back to the two-dispatch
jnp path for shapes the tiled kernel cannot take (last axis not a multiple
of 32 — e.g. tiny KV head_dims — which use the flat ragged wire layout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.gse import (_PACK_CHUNK, PackedGSETensor, gse_pack,
                            gse_quantize, pack_exponents, pack_mantissas)
from repro.kernels.gse_quant import quantize_tile

DEFAULT_BM = 256
DEFAULT_BK = 512


def _fit_block(dim: int, want: int, multiple: int = 1) -> int:
    """Largest block ≤ ``want`` that divides ``dim`` and is a multiple of
    ``multiple`` (callers guarantee ``dim % multiple == 0``)."""
    b = min(want, dim)
    b -= b % multiple
    while b > multiple and dim % b != 0:
        b -= multiple
    return max(b, multiple) if dim % max(b, multiple) == 0 else dim


def _gse_quant_pack_kernel(x_ref, w_ref, e_ref, *, bits: int, group: int,
                           int32_shifts: bool):
    m, e = quantize_tile(x_ref[...], bits, group)  # shared quantize math
    # offset-binary bit-planar pack while the tile sits in VMEM — the int8
    # mantissas never exist outside this kernel. pack_mantissas emits the
    # plane-major (bm, bits*ckb) tile; the output block is the matching
    # (bm, bits, ckb) slice of the global plane-axis view, so each plane
    # lands in its own contiguous run of the wire stream.
    words = pack_mantissas(m.astype(jnp.int8), bits,
                           int32_shifts=int32_shifts)
    w_ref[...] = words.reshape(words.shape[0], bits, -1)
    e_ref[...] = e.astype(jnp.int8)


@functools.partial(jax.jit,
                   static_argnames=("bits", "group", "bm", "bk",
                                    "interpret", "int32_shifts"))
def gse_quant_pack_pallas(x: jax.Array, bits: int = 6, group: int = 32,
                          bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                          interpret: bool = True,
                          int32_shifts: bool = False):
    """x (M, K) float -> (mantissa words (M, K//32*bits) uint32,
    exponents (M, K//group) int8), one fused VMEM pass.

    K % 32 == 0 and K % group == 0 required (the per-row packed layout);
    block shapes are fitted down to divisors of M/K automatically.
    """
    m_dim, k_dim = x.shape
    assert k_dim % _PACK_CHUNK == 0 and k_dim % group == 0, (x.shape, group)
    bm = _fit_block(m_dim, bm)
    bk = _fit_block(k_dim, bk, multiple=int(np.lcm(_PACK_CHUNK, group)))
    assert m_dim % bm == 0 and k_dim % bk == 0, (x.shape, bm, bk)
    ckb = bk // _PACK_CHUNK
    chunks = k_dim // _PACK_CHUNK
    grid = (m_dim // bm, k_dim // bk)
    kernel = functools.partial(_gse_quant_pack_kernel, bits=bits,
                               group=group, int32_shifts=int32_shifts)
    words, exp = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=[
            # (M, bits, chunks) plane-axis view of the plane-major wire
            # stream; each grid step writes its ckb chunk columns of every
            # plane
            pl.BlockSpec((bm, bits, ckb), lambda i, j: (i, 0, j)),
            pl.BlockSpec((bm, bk // group), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_dim, bits, chunks), jnp.uint32),
            jax.ShapeDtypeStruct((m_dim, k_dim // group), jnp.int8),
        ],
        interpret=interpret,
    )(x)
    return words.reshape(m_dim, bits * chunks), exp


# 1-D inputs re-tile to this row width when it divides them: (n/K0, K0)
# grids beat a single (1, n) stripe once n is large.
_FLAT_ROW = 256


@functools.partial(jax.jit,
                   static_argnames=("bits", "group", "interpret", "bm",
                                    "bk", "int32_shifts"))
def gse_quantize_pack(x: jax.Array, bits: int = 6, group: int = 32,
                      interpret: bool = True, bm: int = DEFAULT_BM,
                      bk: int = DEFAULT_BK,
                      int32_shifts: bool = False) -> PackedGSETensor:
    """Quantize + pack ``x`` (any shape, grouped along the last axis) into a
    :class:`PackedGSETensor`, word-for-word identical to
    ``gse_pack(gse_quantize(x, bits, group))``.

    Shapes whose last axis is a multiple of 32 (and of ``group``) run the
    fused Pallas kernel on a 2-D retiling; others (the flat ragged wire
    layout) fall back to the two-dispatch jnp path.
    """
    k = x.shape[-1]
    if k % group != 0:
        raise ValueError(f"last dim {k} not divisible by group {group}")
    if k % _PACK_CHUNK != 0:
        return gse_pack(gse_quantize(x, bits, group))
    if x.ndim == 1:
        k0 = _FLAT_ROW if (k % _FLAT_ROW == 0 and _FLAT_ROW % group == 0
                           and k > _FLAT_ROW) else k
        x2 = x.reshape(-1, k0)
    else:
        x2 = x.reshape(-1, k)
        k0 = k
    words, exp = gse_quant_pack_pallas(x2, bits, group, bm=bm, bk=bk,
                                       interpret=interpret,
                                       int32_shifts=int32_shifts)
    if x.ndim == 1:
        # the flat wire layout is plane-major over the *whole* stream; the
        # 2-D retiling packed each row independently, so restore global
        # plane order: (R, bits, ck0) -> (bits, R, ck0) -> flat
        ck0 = k0 // _PACK_CHUNK
        words = words.reshape(-1, bits, ck0).transpose(1, 0, 2).reshape(-1)
    else:
        # rows pack independently in the per-row layout, so reshaping the
        # 2-D retiling back is exactly the wire layout of the original shape
        words = words.reshape(*x.shape[:-1], bits * (k // _PACK_CHUNK))
    eshape = (*x.shape[:-1], k // group)
    return PackedGSETensor(words, pack_exponents(exp.reshape(eshape)),
                           bits, group, tuple(x.shape))
