"""Jit'd public wrappers for the Pallas kernels with automatic
interpret-mode fallback on CPU (the TPU path passes interpret=False).

These are the entry points the framework would swap in on real TPU for the
QCD hot loops; the jnp fake-quant path remains the simulation default (it
fuses into the surrounding HLO for the dry-run analysis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.gse import PackedGSETensor, unpack_exponents
from repro.kernels.gse_quant import gse_quantize_pallas
from repro.kernels.gse_quant_pack import (gse_quant_pack_pallas,
                                          gse_quantize_pack as
                                          _gse_quantize_pack)
from repro.kernels.gse_matmul import (gse_matmul_pallas,
                                      gse_matmul_packed_pallas)
from repro.kernels.gse_unpack import gse_unpack_pallas
from repro.kernels.nf4_dequant import nf4_dequant_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def gse_quantize(x, bits: int = 6, group: int = 32, **block_kw):
    """(M, K) -> (mantissa int8, exponent int8). Pads M/K to block shape."""
    return gse_quantize_pallas(x, bits, group, interpret=not _on_tpu(),
                               **block_kw)


def gse_quant_pack(x, bits: int = 6, group: int = 32, **block_kw):
    """Fused quantize+pack: (M, K) -> (mantissa words uint32, exponent
    int8) in one VMEM pass — no int8 intermediate in HBM."""
    return gse_quant_pack_pallas(x, bits, group, interpret=not _on_tpu(),
                                 **block_kw)


def gse_quantize_pack(x, bits: int = 6, group: int = 32,
                      **block_kw) -> PackedGSETensor:
    """Shape-polymorphic fused quantize+pack to a PackedGSETensor (kernel
    when the last axis is 32-aligned, jnp fallback for ragged layouts)."""
    return _gse_quantize_pack(x, bits, group, interpret=not _on_tpu(),
                              **block_kw)


def gse_unpack(words, bits: int, **block_kw):
    """Packed mantissa words (M, K//32*bits) uint32 -> int8 (M, K)."""
    return gse_unpack_pallas(words, bits, interpret=not _on_tpu(),
                             **block_kw)


def gse_matmul(a_m, a_e, b_m, b_e, group: int = 32, **block_kw):
    """GSE (M,K) x (N,K) -> fp32 (M,N) via int8 MXU MACs."""
    return gse_matmul_pallas(a_m, a_e, b_m, b_e, group,
                             interpret=not _on_tpu(), **block_kw)


def gse_matmul_packed(a_m, a_e, b_words, b_e, bits: int, group: int = 32,
                      **block_kw):
    """Fused packed-dequant matmul: B mantissas stay packed in HBM."""
    return gse_matmul_packed_pallas(a_m, a_e, b_words, b_e, bits, group,
                                    interpret=not _on_tpu(), **block_kw)


def nf4_dequant(codes, absmax, out_dtype=jnp.bfloat16, **block_kw):
    return nf4_dequant_pallas(codes, absmax, out_dtype,
                              interpret=not _on_tpu(), **block_kw)


def gse_linear(x, w, bits: int = 6, group: int = 32):
    """End-to-end quantized linear through the kernel path:
    quantize x and w along K, integer matmul, fp32 out.

    x: (B, K) float; w: (N, K) float -> (B, N) fp32.
    """
    xm, xe = gse_quantize(x, bits, group)
    wm, we = gse_quantize(w, bits, group)
    return gse_matmul(xm, xe, wm, we, group)


def gse_linear_packed(x, w_packed: PackedGSETensor, **block_kw):
    """Linear against a weight held in packed GSE storage: quantize the
    activation on the fly, feed the packed words straight into the fused
    kernel. Only the activation's (tiny) exponents are unpacked host-side;
    the weight mantissas go HBM -> VMEM as b-bit words.

    x: (B, K) float; w_packed: logical (N, K) -> (B, N) fp32.
    """
    bits, group = w_packed.bits, w_packed.group_size
    xm, xe = gse_quantize(x, bits, group)
    we = unpack_exponents(w_packed.exponent_words, w_packed.exponent_shape)
    return gse_matmul_packed(xm, xe, w_packed.mantissa_words, we, bits,
                             group, **block_kw)
