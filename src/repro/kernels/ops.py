"""Jit'd public wrappers for the Pallas kernels with automatic
interpret-mode fallback on CPU (the TPU path passes interpret=False).

These are the entry points the framework would swap in on real TPU for the
QCD hot loops; the jnp fake-quant path remains the simulation default (it
fuses into the surrounding HLO for the dry-run analysis).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gse import PackedGSETensor, unpack_exponents
from repro.kernels.gse_quant import gse_quantize_pallas
from repro.kernels.gse_quant_pack import (gse_quant_pack_pallas,
                                          gse_quantize_pack as
                                          _gse_quantize_pack)
from repro.kernels.gse_matmul import (gse_matmul_pallas,
                                      gse_matmul_packed_pallas)
from repro.kernels.gse_unpack import gse_unpack_pallas
from repro.kernels.nf4_dequant import nf4_dequant_pallas
from repro.kernels import flash_attention_packed as fap


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# uint32 shifts are not lowered by every Mosaic version; the packed kernels
# can run the identical shift/mask math on bitcast int32 words instead
# (bit-identical output — see repro.core.gse.pack_unsigned). "auto" enables
# the fallback on TPU only; force with REPRO_GSE_INT32_SHIFTS=1/0.


def int32_shift_fallback() -> bool:
    env = os.environ.get("REPRO_GSE_INT32_SHIFTS", "auto").lower()
    if env in ("1", "true", "on"):
        return True
    if env in ("0", "false", "off"):
        return False
    return _on_tpu()


def gse_quantize(x, bits: int = 6, group: int = 32, **block_kw):
    """(M, K) -> (mantissa int8, exponent int8). Pads M/K to block shape."""
    return gse_quantize_pallas(x, bits, group, interpret=not _on_tpu(),
                               **block_kw)


def gse_quant_pack(x, bits: int = 6, group: int = 32, **block_kw):
    """Fused quantize+pack: (M, K) -> (mantissa words uint32, exponent
    int8) in one VMEM pass — no int8 intermediate in HBM."""
    block_kw.setdefault("int32_shifts", int32_shift_fallback())
    return gse_quant_pack_pallas(x, bits, group, interpret=not _on_tpu(),
                                 **block_kw)


def gse_quantize_pack(x, bits: int = 6, group: int = 32,
                      **block_kw) -> PackedGSETensor:
    """Shape-polymorphic fused quantize+pack to a PackedGSETensor (kernel
    when the last axis is 32-aligned, jnp fallback for ragged layouts)."""
    block_kw.setdefault("int32_shifts", int32_shift_fallback())
    return _gse_quantize_pack(x, bits, group, interpret=not _on_tpu(),
                              **block_kw)


def gse_unpack(words, bits: int, **block_kw):
    """Packed mantissa words (M, K//32*bits) uint32 -> int8 (M, K)."""
    block_kw.setdefault("int32_shifts", int32_shift_fallback())
    return gse_unpack_pallas(words, bits, interpret=not _on_tpu(),
                             **block_kw)


def gse_matmul(a_m, a_e, b_m, b_e, group: int = 32, **block_kw):
    """GSE (M,K) x (N,K) -> fp32 (M,N) via int8 MXU MACs."""
    return gse_matmul_pallas(a_m, a_e, b_m, b_e, group,
                             interpret=not _on_tpu(), **block_kw)


def gse_matmul_packed(a_m, a_e, b_words, b_e, bits: int, group: int = 32,
                      **block_kw):
    """Fused packed-dequant matmul: B mantissas stay packed in HBM."""
    block_kw.setdefault("int32_shifts", int32_shift_fallback())
    return gse_matmul_packed_pallas(a_m, a_e, b_words, b_e, bits, group,
                                    interpret=not _on_tpu(), **block_kw)


def nf4_dequant(codes, absmax, out_dtype=jnp.bfloat16, **block_kw):
    return nf4_dequant_pallas(codes, absmax, out_dtype,
                              interpret=not _on_tpu(), **block_kw)


def gse_linear(x, w, bits: int = 6, group: int = 32):
    """End-to-end quantized linear through the kernel path:
    quantize x and w along K, integer matmul, fp32 out.

    x: (B, K) float; w: (N, K) float -> (B, N) fp32.
    """
    xm, xe = gse_quantize(x, bits, group)
    wm, we = gse_quantize(w, bits, group)
    return gse_matmul(xm, xe, wm, we, group)


def quant_pack_kv_rows(x, bits: int, group: int = 32):
    """Row-planar KV quantize+pack: (..., D) float -> (words, int8 exps)
    via the fused kernel when D is 32-aligned (the decode append path)."""
    return fap.quant_pack_kv_rows(x, bits, group,
                                  interpret=not _on_tpu(),
                                  int32_shifts=int32_shift_fallback())


def dequant_kv_rows(words, exps, head_dim: int, dtype=jnp.float32):
    """Row-planar planes -> values (..., head_dim). Full materialization —
    tests/inspection only; the attention hot path never calls this on a
    whole cache."""
    return fap.dequant_kv_rows(words, exps, head_dim, dtype,
                               int32_shifts=int32_shift_fallback())


def flash_attention_packed(q, k_words, k_exp, v_words, v_exp, *,
                           causal: bool = True, window: int = 0,
                           q_offset=0, is_global=None,
                           bq: int = 256, bk: int = 512):
    """Fused packed-KV flash attention dispatcher.

    q (B, T, H, D); planes (B, S, Kv, ·) in the row-planar packed layout.
    On TPU with MHA-shaped static inputs the Pallas kernel runs (K/V tiles
    unpacked in VMEM only); everywhere else — GQA, traced decode offsets,
    per-layer ``is_global`` overrides, ragged lengths, interpret/CPU — the
    tile-local jnp fallback runs the same math one KV tile at a time.
    """
    b, t, h, d = q.shape
    s_len, kv = k_words.shape[1], k_words.shape[2]
    static_off = isinstance(q_offset, (int, np.integer))
    fits = (t % min(bq, t) == 0 and s_len % min(bk, s_len) == 0)
    if _on_tpu() and h == kv and static_off and is_global is None and fits:
        def fold(x):                      # (B, S, H, ·) -> (B*H, S, ·)
            return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], -1)
        o = fap.flash_attention_packed_pallas(
            fold(q), fold(k_words), fold(k_exp), fold(v_words),
            fold(v_exp), causal=causal, window=window,
            q_offset=int(q_offset), bq=bq, bk=bk, interpret=False,
            int32_shifts=int32_shift_fallback())
        return o.reshape(b, h, t, d).transpose(0, 2, 1, 3)
    return fap.flash_attention_packed_jnp(
        q, k_words, k_exp, v_words, v_exp, causal=causal, window=window,
        q_offset=q_offset, is_global=is_global, k_chunk=bk,
        int32_shifts=int32_shift_fallback())


def gse_linear_packed(x, w_packed: PackedGSETensor, **block_kw):
    """Linear against a weight held in packed GSE storage: quantize the
    activation on the fly, feed the packed words straight into the fused
    kernel. Only the activation's (tiny) exponents are unpacked host-side;
    the weight mantissas go HBM -> VMEM as b-bit words.

    x: (B, K) float; w_packed: logical (N, K) -> (B, N) fp32.
    """
    bits, group = w_packed.bits, w_packed.group_size
    xm, xe = gse_quantize(x, bits, group)
    we = unpack_exponents(w_packed.exponent_words, w_packed.exponent_shape)
    return gse_matmul_packed(xm, xe, w_packed.mantissa_words, we, bits,
                             group, **block_kw)
