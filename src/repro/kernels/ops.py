"""Jit'd public wrappers for the Pallas kernels with automatic
interpret-mode fallback on CPU (the TPU path passes interpret=False).

These are the entry points the framework would swap in on real TPU for the
QCD hot loops; the jnp fake-quant path remains the simulation default (it
fuses into the surrounding HLO for the dry-run analysis).
"""
from __future__ import annotations

import functools
import logging
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.gse import (PackedGSETensor, _PACK_CHUNK, gse_dequantize_in,
                            unpack_exponents)
from repro.kernels.gse_quant import gse_quantize_pallas
from repro.kernels.gse_quant_pack import (_fit_block, gse_quant_pack_pallas,
                                          gse_quantize_pack as
                                          _gse_quantize_pack)
from repro.kernels.gse_matmul import (gse_matmul_pallas,
                                      gse_matmul_packed_pallas,
                                      gse_matmul_packed_nt_pallas,
                                      gse_matmul_packed_tn_pallas)
from repro.kernels.gse_unpack import gse_unpack_pallas
from repro.kernels.nf4_dequant import nf4_dequant_pallas
from repro.kernels import flash_attention_packed as fap


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _env_tristate_raw(name: str):
    """Shared 1/0/auto env-flag vocabulary: True / False / None (auto —
    unset or any unrecognized value defers to the caller's default)."""
    env = os.environ.get(name, "auto").lower()
    if env in ("1", "true", "on"):
        return True
    if env in ("0", "false", "off"):
        return False
    return None


def _env_tristate(name: str, default_fn) -> bool:
    """Shared 1/0/auto env-flag reader for the kernel-path toggles."""
    forced = _env_tristate_raw(name)
    return default_fn() if forced is None else forced


# uint32 shifts are not lowered by every Mosaic version; the packed kernels
# can run the identical shift/mask math on bitcast int32 words instead
# (bit-identical output — see repro.core.gse.pack_unsigned). "auto" enables
# the fallback on TPU only; force with REPRO_GSE_INT32_SHIFTS=1/0.


def int32_shift_fallback() -> bool:
    return _env_tristate("REPRO_GSE_INT32_SHIFTS", _on_tpu)


def qcd_f32_out() -> bool:
    """Single reader for REPRO_QCD_F32_OUT (the fp32-GEMM-output ablation of
    the QCD training path — repro.core.qcd); read at trace time. Same
    1/0/auto vocabulary as every other kernel knob (auto/unset = off) —
    this used to be a bespoke any-non-empty-truthy reader, the last one in
    this module."""
    return _env_tristate("REPRO_QCD_F32_OUT", lambda: False)


def int_mac_requested():
    """REPRO_INT_MAC tri-state: 1/0 force the integer-MAC mode of the
    packed kernels on/off regardless of the QuantPolicy flag / call
    argument; auto (default) defers to the caller."""
    return _env_tristate_raw("REPRO_INT_MAC")


def resolve_int_mac(flag: bool) -> bool:
    """Combine a caller/policy ``int_mac`` flag with the env override."""
    forced = int_mac_requested()
    return bool(flag) if forced is None else forced


def nf4_flat_dequant() -> bool:
    """Single reader for REPRO_NF4_FLAT_DEQUANT (forces the flat (-1, 64)
    NF4 dequant layout instead of the shape-preserving path — the dry-run
    A/B in repro.launch.dryrun). Same 1/0/auto vocabulary as every other
    knob (auto/unset = off); formerly a bespoke any-non-empty-truthy read
    of os.environ inside repro.core.nf4."""
    return _env_tristate("REPRO_NF4_FLAT_DEQUANT", lambda: False)


def qcd_packed_kernels() -> bool:
    """Route the packed-residual QCD GEMMs through the Pallas kernels.

    "auto" = TPU only (the jnp dequant fallback is the CPU simulation path
    and is bit-identical to the fake-quant training math); force with
    REPRO_QCD_PACKED_KERNELS=1 to exercise the kernel path in interpret
    mode (tests/benches — fp32 tile-ordered accumulation, no longer
    bit-identical to the bf16 simulation)."""
    return _env_tristate("REPRO_QCD_PACKED_KERNELS", _on_tpu)


# Every boolean kernel knob and its reader, all speaking the same 1/0/auto
# vocabulary (the regression test sweeps this table). REPRO_INT_MAC is the
# tri-state override for the integer-MAC kernel modes; its table entry
# resolves against an ``auto -> off`` caller default.
ENV_TRISTATE_KNOBS = {
    "REPRO_GSE_INT32_SHIFTS": lambda: int32_shift_fallback(),
    "REPRO_QCD_PACKED_KERNELS": lambda: qcd_packed_kernels(),
    "REPRO_QCD_F32_OUT": lambda: qcd_f32_out(),
    "REPRO_INT_MAC": lambda: resolve_int_mac(False),
    "REPRO_NF4_FLAT_DEQUANT": lambda: nf4_flat_dequant(),
}


def gse_quantize(x, bits: int = 6, group: int = 32, **block_kw):
    """(M, K) -> (mantissa int8, exponent int8). Pads M/K to block shape."""
    return gse_quantize_pallas(x, bits, group, interpret=not _on_tpu(),
                               **block_kw)


def gse_quant_pack(x, bits: int = 6, group: int = 32, **block_kw):
    """Fused quantize+pack: (M, K) -> (mantissa words uint32, exponent
    int8) in one VMEM pass — no int8 intermediate in HBM."""
    block_kw.setdefault("int32_shifts", int32_shift_fallback())
    return gse_quant_pack_pallas(x, bits, group, interpret=not _on_tpu(),
                                 **block_kw)


def gse_quantize_pack(x, bits: int = 6, group: int = 32,
                      **block_kw) -> PackedGSETensor:
    """Shape-polymorphic fused quantize+pack to a PackedGSETensor (kernel
    when the last axis is 32-aligned, jnp fallback for ragged layouts)."""
    block_kw.setdefault("int32_shifts", int32_shift_fallback())
    return _gse_quantize_pack(x, bits, group, interpret=not _on_tpu(),
                              **block_kw)


def gse_unpack(words, bits: int, **block_kw):
    """Packed mantissa words (M, K//32*bits) uint32 -> int8 (M, K)."""
    block_kw.setdefault("int32_shifts", int32_shift_fallback())
    return gse_unpack_pallas(words, bits, interpret=not _on_tpu(),
                             **block_kw)


def gse_matmul(a_m, a_e, b_m, b_e, group: int = 32, **block_kw):
    """GSE (M,K) x (N,K) -> fp32 (M,N) via int8 MXU MACs."""
    return gse_matmul_pallas(a_m, a_e, b_m, b_e, group,
                             interpret=not _on_tpu(), **block_kw)


def gse_matmul_packed(a_m, a_e, b_words, b_e, bits: int, group: int = 32,
                      **block_kw):
    """Fused packed-dequant matmul: B mantissas stay packed in HBM."""
    block_kw.setdefault("int32_shifts", int32_shift_fallback())
    return gse_matmul_packed_pallas(a_m, a_e, b_words, b_e, bits, group,
                                    interpret=not _on_tpu(), **block_kw)


def gse_matmul_packed_nt(a_words, a_e, b_words, b_e, a_bits: int,
                         b_bits: int, a_group: int = 32, b_group: int = 32,
                         **block_kw):
    """Transposed-contraction packed matmul (the dX backward GEMM): both
    operands arrive as packed word streams, tiles dequantized in VMEM."""
    block_kw.setdefault("int32_shifts", int32_shift_fallback())
    return gse_matmul_packed_nt_pallas(a_words, a_e, b_words, b_e, a_bits,
                                       b_bits, a_group, b_group,
                                       interpret=not _on_tpu(), **block_kw)


def gse_matmul_packed_tn(a_words, a_e, b_words, b_e, a_bits: int,
                         b_bits: int, a_group: int = 32, b_group: int = 32,
                         **block_kw):
    """Token-contraction packed matmul (the dW backward GEMM): contraction
    over the shared leading axis of two packed operands."""
    block_kw.setdefault("int32_shifts", int32_shift_fallback())
    return gse_matmul_packed_tn_pallas(a_words, a_e, b_words, b_e, a_bits,
                                       b_bits, a_group, b_group,
                                       interpret=not _on_tpu(), **block_kw)


def nf4_dequant(codes, absmax, out_dtype=jnp.bfloat16, **block_kw):
    return nf4_dequant_pallas(codes, absmax, out_dtype,
                              interpret=not _on_tpu(), **block_kw)


def gse_linear(x, w, bits: int = 6, group: int = 32):
    """End-to-end quantized linear through the kernel path:
    quantize x and w along K, integer matmul, fp32 out.

    x: (B, K) float; w: (N, K) float -> (B, N) fp32.
    """
    xm, xe = gse_quantize(x, bits, group)
    wm, we = gse_quantize(w, bits, group)
    return gse_matmul(xm, xe, wm, we, group)


def quant_pack_kv_rows(x, bits: int, group: int = 32):
    """Row-planar KV quantize+pack: (..., D) float -> (words, int8 exps)
    via the fused kernel when D is 32-aligned (the decode append path)."""
    return fap.quant_pack_kv_rows(x, bits, group,
                                  interpret=not _on_tpu(),
                                  int32_shifts=int32_shift_fallback())


def dequant_kv_rows(words, exps, head_dim: int, dtype=jnp.float32):
    """Row-planar planes -> values (..., head_dim). Full materialization —
    tests/inspection only; the attention hot path never calls this on a
    whole cache."""
    return fap.dequant_kv_rows(words, exps, head_dim, dtype,
                               int32_shifts=int32_shift_fallback())


# ---------------------------------------------------------------------------
# Packed-KV flash attention dispatch. The kernel serves GQA shapes and
# traced decode offsets (scalar prefetch); the jnp fallback keeps the cases
# the static grid cannot take (traced is_global, ragged tile lengths) and
# the CPU simulation default. REPRO_FAP_ROUTE=kernel|fallback|auto forces
# either side ("kernel" runs interpret mode off-TPU); every dispatch
# records its decision (last_fap_route) and debug-logs the reason.
# ---------------------------------------------------------------------------

_fap_log = logging.getLogger("repro.kernels.flash_attention_packed")
_LAST_FAP_ROUTE = ("", "never dispatched")


def fap_route() -> str:
    """REPRO_FAP_ROUTE reader: 'kernel' | 'fallback' | 'auto'."""
    env = os.environ.get("REPRO_FAP_ROUTE", "auto").lower()
    if env in ("kernel", "pallas", "1", "true", "on"):
        return "kernel"
    if env in ("fallback", "jnp", "0", "false", "off"):
        return "fallback"
    return "auto"


def last_fap_route():
    """(route, reason) of the most recent flash_attention_packed dispatch
    — the observable half of the routing contract (tests/debugging)."""
    return _LAST_FAP_ROUTE


def concrete_scalar_int(x):
    """int for any *concrete* 0-d offset — python/np ints, 0-d np arrays,
    concrete jax scalars (weak-typed included) — else None (tracers).
    Normalizing here keeps concrete offsets on one jit cache key and makes
    the routing independent of which scalar flavor the caller held."""
    if isinstance(x, (int, np.integer)):
        return int(x)
    if isinstance(x, np.ndarray) and x.ndim == 0:
        return int(x)
    if isinstance(x, jax.Array) and x.ndim == 0 and jax.core.is_concrete(x):
        return int(x)
    return None


def fap_route_decision(t: int, s_len: int, h: int, kv: int, *,
                       has_is_global: bool, bq: int, bk: int):
    """Pure routing decision for :func:`flash_attention_packed`.

    Returns (use_kernel, reason). Traced ``q_offset`` and GQA shapes are
    kernel-eligible (scalar prefetch / GQA grid); only traced ``is_global``
    overrides, ragged tile lengths, and non-grouping head counts force the
    fallback regardless of REPRO_FAP_ROUTE.
    """
    mode = fap_route()
    if has_is_global:
        return False, ("traced is_global override (per-layer global "
                       f"attention) needs the jnp fallback [mode={mode}]")
    if kv == 0 or h % kv:
        return False, (f"q heads {h} not a multiple of kv heads {kv} "
                       f"[mode={mode}]")
    if t % min(bq, t) or s_len % min(bk, s_len):
        return False, (f"ragged tiles: T={t} S={s_len} vs bq={bq} bk={bk} "
                       f"[mode={mode}]")
    if mode == "kernel":
        return True, "forced by REPRO_FAP_ROUTE=kernel"
    if mode == "fallback":
        return False, "forced by REPRO_FAP_ROUTE=fallback"
    if _on_tpu():
        return True, "auto: tpu backend"
    return False, "auto: non-tpu backend runs the jnp simulation path"


def flash_attention_packed(q, k_words, k_exp, v_words, v_exp, *,
                           causal: bool = True, window: int = 0,
                           q_offset=0, is_global=None,
                           k_tail=None, v_tail=None,
                           bq: int = 256, bk: int = 512,
                           int_mac: bool = False,
                           kv_active_bits: int | None = None,
                           kv_trunc=None):
    """Fused packed-KV flash attention dispatcher.

    q (B, T, H, D); planes (B, S, Kv, ·) in the row-planar packed layout;
    optional ``k_tail``/``v_tail`` (B, Tt, Kv, D) fp rows for the
    quantize-after-attend decode append. The Pallas kernel serves GQA
    shapes (folded by kv-head — packed planes are never expanded) and
    traced decode offsets (scalar prefetch); traced ``is_global`` and
    ragged tile lengths run the tile-local jnp fallback, which computes
    the identical float sequence one KV tile at a time.

    ``q_offset`` may be a scalar (all sequences at one position — the
    static-batch path) or a per-sequence (B,) vector (ragged batches:
    each row's positions/masks use its own offset on both routes).

    ``int_mac=True`` (or REPRO_INT_MAC=1) runs the score GEMM on the
    exact-tier integer path — in-tile q quantization, int8 MACs, rank-1
    rescale — on BOTH routes (same int sequence, kernel == fallback
    bitwise).

    ``kv_active_bits`` reads only the first b mantissa planes of the
    stored KV (plane-prefix view, docs/gse-format.md §7) — floor
    truncation against the same shared exponents, identical on both
    routes. ``kv_trunc`` (traced scalar or per-sequence (B,) vector)
    shifts *additional* planes below the active width per sequence
    (mixed-precision serving lanes); incompatible with ``int_mac``.
    """
    global _LAST_FAP_ROUTE
    b, t, h, d = q.shape
    s_len, kv = k_words.shape[1], k_words.shape[2]
    int_mac = resolve_int_mac(int_mac)
    off = concrete_scalar_int(q_offset)
    if off is not None:
        q_offset = off
    use_kernel, reason = fap_route_decision(
        t, s_len, h, kv, has_is_global=is_global is not None, bq=bq, bk=bk)
    if kv_trunc is not None and use_kernel:
        # the planar kernel grid has no trunc prefetch lane (only the paged
        # kernel does) — per-sequence truncation runs the jnp fallback
        use_kernel = False
        reason = "traced kv_trunc (per-sequence plane shifts) needs the " \
                 "jnp fallback"
    reason += " [int-mac scores]" if int_mac else ""
    if kv_active_bits is not None:
        reason += f" [kv plane prefix b={kv_active_bits}]"
    _LAST_FAP_ROUTE = ("kernel" if use_kernel else "fallback", reason)
    _fap_log.debug("flash_attention_packed -> %s (%s)",
                   _LAST_FAP_ROUTE[0], reason)
    if use_kernel:
        g = h // kv

        def fold(x):                      # (B, S, Kv, ·) -> (B*Kv, S, ·)
            return x.transpose(0, 2, 1, 3).reshape(b * kv, x.shape[1], -1)
        qf = q.reshape(b, t, kv, g, d).transpose(0, 2, 3, 1, 4).reshape(
            b * kv, g, t, d)
        tails = {}
        if k_tail is not None:
            tails = dict(k_tail=fold(k_tail), v_tail=fold(v_tail))
        # per-sequence (B,) offsets expand to the folded (B*Kv,) layout —
        # b-major kv-minor, matching the q fold above
        if getattr(q_offset, "ndim", 0):
            q_offset = jnp.repeat(jnp.asarray(q_offset, jnp.int32), kv)
        o = fap.flash_attention_packed_pallas(
            qf, fold(k_words), fold(k_exp), fold(v_words), fold(v_exp),
            causal=causal, window=window, q_offset=q_offset, bq=bq, bk=bk,
            interpret=not _on_tpu(), int32_shifts=int32_shift_fallback(),
            int_mac=int_mac, kv_active_bits=kv_active_bits, **tails)
        return o.reshape(b, kv, g, t, d).transpose(0, 3, 1, 2, 4).reshape(
            b, t, h, d)
    return fap.flash_attention_packed_jnp(
        q, k_words, k_exp, v_words, v_exp, causal=causal, window=window,
        q_offset=q_offset, is_global=is_global, k_tail=k_tail,
        v_tail=v_tail, k_chunk=bk, int32_shifts=int32_shift_fallback(),
        int_mac=int_mac, kv_active_bits=kv_active_bits, kv_trunc=kv_trunc)


_LAST_PAGED_ROUTE = ("", "never dispatched")


def last_paged_route():
    """(route, reason) of the most recent flash_attention_paged dispatch —
    same observability contract as last_fap_route."""
    return _LAST_PAGED_ROUTE


def flash_attention_paged(q, kp_words, kp_exp, vp_words, vp_exp,
                          page_table, *, causal: bool = True,
                          window: int = 0, q_offset=0, is_global=None,
                          k_tail=None, v_tail=None, bq: int = 256,
                          k_chunk: int | None = None,
                          int_mac: bool = False,
                          kv_active_bits: int | None = None,
                          kv_trunc=None):
    """Paged packed-KV flash attention dispatcher.

    q (B, T, H, D); pools (P, page, Kv, ·) — the row-planar planes carved
    into fixed pages (docs/gse-format.md §4); page_table (B, maxp) int32
    physical page ids per logical page. ``q_offset`` is typically a
    per-sequence (B,) vector (ragged serving batches).

    Kernel route: the page table and offset vector ride as scalar-prefetch
    SMEM operands; the grid walks each sequence's pages in logical order,
    fetching pages straight from the pool via the BlockSpec index maps —
    no gather, no fp materialization. Fallback route: :func:`gather_pages`
    moves the *packed* words/exponents into the logical (B, maxp·page, ·)
    planar view and runs the planar jnp path (the bit-exact oracle at
    ``k_chunk == page``). Routing speaks the same REPRO_FAP_ROUTE knob and
    eligibility rules as the planar dispatcher.

    ``kv_active_bits`` reads the first b mantissa planes of each page
    (static plane-prefix view over the pool's stored width); ``kv_trunc``
    is a per-sequence (B,) int32 vector of *additional* plane shifts below
    the active width — it rides the scalar-prefetch lane beside the page
    table and offset vector, so one fused decode block serves lanes at
    mixed effective widths from the one pool. Both are floor truncation
    against the shared exponents on both routes; ``kv_trunc`` is
    incompatible with ``int_mac``.
    """
    global _LAST_PAGED_ROUTE
    b, t, h, d = q.shape
    _, page, kv, _ = kp_words.shape
    maxp = page_table.shape[1]
    int_mac = resolve_int_mac(int_mac)
    use_kernel, reason = fap_route_decision(
        t, maxp * page, h, kv, has_is_global=is_global is not None,
        bq=bq, bk=page)
    reason += " [int-mac scores]" if int_mac else ""
    if kv_active_bits is not None:
        reason += f" [kv plane prefix b={kv_active_bits}]"
    if kv_trunc is not None:
        reason += " [per-seq kv trunc]"
    _LAST_PAGED_ROUTE = ("kernel" if use_kernel else "fallback",
                         "paged: " + reason)
    _fap_log.debug("flash_attention_paged -> %s (%s)",
                   _LAST_PAGED_ROUTE[0], reason)
    if use_kernel:
        g = h // kv

        def fold(x):                      # (B, Tt, Kv, ·) -> (B*Kv, Tt, ·)
            return x.transpose(0, 2, 1, 3).reshape(b * kv, x.shape[1], -1)
        qf = q.reshape(b, t, kv, g, d).transpose(0, 2, 3, 1, 4).reshape(
            b * kv, g, t, d)
        tails = {}
        if k_tail is not None:
            tails = dict(k_tail=fold(k_tail), v_tail=fold(v_tail))
        off = jnp.asarray(q_offset, jnp.int32)
        if off.ndim:                      # (B,) -> folded (B*Kv,)
            off = jnp.repeat(off, kv)
        o = fap.flash_attention_paged_pallas(
            qf, kp_words, kp_exp, vp_words, vp_exp,
            jnp.asarray(page_table, jnp.int32), q_offset=off,
            causal=causal, window=window, bq=bq,
            interpret=not _on_tpu(), int32_shifts=int32_shift_fallback(),
            int_mac=int_mac, kv_active_bits=kv_active_bits,
            kv_trunc=kv_trunc, **tails)
        return o.reshape(b, kv, g, t, d).transpose(0, 3, 1, 2, 4).reshape(
            b, t, h, d)
    pt = jnp.asarray(page_table, jnp.int32)
    return fap.flash_attention_packed_jnp(
        q, fap.gather_pages(kp_words, pt), fap.gather_pages(kp_exp, pt),
        fap.gather_pages(vp_words, pt), fap.gather_pages(vp_exp, pt),
        causal=causal, window=window, q_offset=q_offset,
        is_global=is_global, k_tail=k_tail, v_tail=v_tail,
        k_chunk=k_chunk or page, int32_shifts=int32_shift_fallback(),
        int_mac=int_mac, kv_active_bits=kv_active_bits, kv_trunc=kv_trunc)


# ---------------------------------------------------------------------------
# QCD packed-residual GEMM dispatchers (the training path, repro.core.qcd).
#
# Each takes packed GSE operands (PackedGSETensor — or a raw array for an
# unquantized operand) and routes: Pallas kernels when qcd_packed_kernels()
# (TPU, or forced via REPRO_QCD_PACKED_KERNELS=1 for interpret-mode tests),
# otherwise an exact-dequant jnp fallback whose op sequence is the SAME XLA
# matmul the fake-quant simulation runs — bit-identical training math, which
# is what makes the packed/fake-quant A/B parity an array_equal, not an
# allclose. The kernel path instead follows the ordered-accumulation
# contract (fp32 tile MACs), bit-exact vs the ref.py oracles.
#
# Every dispatch records its decision per GEMM (last_qcd_route) and
# debug-logs the reason — the same observability contract the attention
# dispatcher carries (last_fap_route); forced-env is no longer the only
# probe of which path actually ran.
# ---------------------------------------------------------------------------

_qcd_log = logging.getLogger("repro.kernels.qcd")
_LAST_QCD_ROUTE = {
    "y": ("", "never dispatched"),
    "dx": ("", "never dispatched"),
    "dw": ("", "never dispatched"),
}


def last_qcd_route(gemm: str | None = None):
    """(route, reason) of the most recent QCD GEMM dispatch.

    ``gemm`` is "y" (forward), "dx" or "dw" (backward); with no argument
    the whole {gemm: (route, reason)} dict is returned. Route is "kernel"
    or "fallback" ("" before the first dispatch); reasons carry the
    deciding condition plus the MAC mode of the chosen path."""
    if gemm is None:
        return dict(_LAST_QCD_ROUTE)
    return _LAST_QCD_ROUTE[gemm]


_QCD_OPERAND_NAMES = {"y": ("x", "w"), "dx": ("dy", "w"), "dw": ("x", "dy")}


def _qcd_route(gemm: str, operands, *, group_match: bool = True,
               mac: str = "fp32 tile MACs") -> bool:
    """Route one QCD GEMM: returns use_kernel, recording (route, reason)
    under ``gemm`` and debug-logging it. ``mac`` names the kernel path's
    MAC mode for the reason string; the fallback is always the exact-
    dequant XLA matmul."""
    names = _QCD_OPERAND_NAMES[gemm]

    def record(use_kernel: bool, reason: str) -> bool:
        route = "kernel" if use_kernel else "fallback"
        _LAST_QCD_ROUTE[gemm] = (route, reason)
        _qcd_log.debug("qcd_matmul_%s -> %s (%s)", gemm, route, reason)
        return use_kernel

    for name, t in zip(names, operands):
        if not _is_packed(t):
            return record(False, f"{name} operand is not packed GSE "
                          "(fake-quant simulation / raw array)")
    if not qcd_packed_kernels():
        return record(False, "qcd_packed_kernels() off: exact-dequant jnp "
                      "fallback (bit-identical to fake-quant)")
    for name, t in zip(names, operands):
        if not _rows_packable(t):
            return record(False, f"{name} words are flat-stream (last axis "
                          f"{t.shape[-1]} not 32-aligned)")
    if not group_match:
        return record(False, "operand group sizes differ "
                      f"({operands[0].group_size} vs {operands[1].group_size})")
    return record(True, f"packed operands on the kernel path [{mac}]")


def _is_packed(t) -> bool:
    return isinstance(t, PackedGSETensor)


def _rows_packable(p: PackedGSETensor) -> bool:
    """Per-row word layout (last axis 32-aligned) — reshapeable to the 2-D
    kernel ABI. The ragged flat-stream layout always takes the fallback."""
    return p.shape[-1] % _PACK_CHUNK == 0


def _words_2d(p: PackedGSETensor):
    return p.mantissa_words.reshape(-1, p.mantissa_words.shape[-1])


def _exps_2d(p: PackedGSETensor):
    e = unpack_exponents(p.exponent_words, p.exponent_shape)
    if p.exp_shift:
        # plane-prefix view: the kernels decode the (narrowed) words at
        # p.bits == active_bits, so the truncation's exponent compensation
        # folds here, once, outside the kernels (max 15 + 6 fits int8)
        e = (e.astype(jnp.int32) + p.exp_shift).astype(jnp.int8)
    return e.reshape(-1, e.shape[-1])


def _deq(t, dtype):
    """Exact dequant of a packed operand in ``dtype`` (raw arrays pass
    through) — repro.core.gse.gse_dequantize_in, the fake-quant-identical
    multiply."""
    return gse_dequantize_in(t, dtype) if _is_packed(t) else t


def _fit(dim: int, want: int, group: int = 1) -> int:
    return _fit_block(dim, want, multiple=int(np.lcm(_PACK_CHUNK, group)))


def qcd_matmul_y(xq, wq, *, compute_dtype, f32_out: bool = False):
    """Forward Y = Q(X) @ Q(W) from packed operands.

    xq: logical (..., K) grouped/packed along K; wq: logical (N, K) packed
    along K — the W^T layout the residual stores. Returns (..., N).
    Kernel route: the fused packed-dequant int8 MXU matmul (weights stream
    HBM->VMEM at b bits/value; the activation unpacks to a transient int8
    working array, never to float)."""
    if _qcd_route("y", (xq, wq),
                  group_match=(not (_is_packed(xq) and _is_packed(wq))
                               or xq.group_size == wq.group_size),
                  mac="int8 MXU group MACs"):
        k = xq.shape[-1]
        g = xq.group_size
        xm = gse_unpack(_words_2d(xq), xq.bits,
                        bm=_fit_block(int(np.prod(xq.shape[:-1])), 256),
                        bk=_fit(k, 512))
        y = gse_matmul_packed(
            xm, _exps_2d(xq), wq.mantissa_words, _exps_2d(wq), wq.bits, g,
            bm=_fit_block(xm.shape[0], 128), bn=_fit_block(wq.shape[0], 128),
            bk=_fit(k, 512, g))
        return y.reshape(*xq.shape[:-1], -1).astype(compute_dtype)
    xd = _deq(xq, compute_dtype)
    wd = _deq(wq, compute_dtype)            # (N, K) -> contract as x @ wd.T
    if f32_out:
        return jnp.matmul(xd, wd.T, preferred_element_type=jnp.float32
                          ).astype(compute_dtype)
    return jnp.matmul(xd, wd.T)


def qcd_matmul_dx(dyq, wq, *, compute_dtype, f32_out: bool = False,
                  int_mac: bool = False):
    """Backward dX = Q(dY) @ Q(W)^T — contraction over N.

    dyq: logical (..., N) grouped/packed along N (raw array when g_bits is
    None); wq: logical (N, K) packed along K (the saved forward-grouped
    residual — no per-use re-grouping). Kernel route: the
    transposed-contraction packed matmul, both operands tile-dequantized in
    VMEM — or, with ``int_mac`` (bounded tier, REPRO_INT_MAC overrides),
    realigned to tile-shared exponents and MAC'd in int32. The fallback is
    always exact-dequant (``int_mac`` has no effect there)."""
    int_mac = resolve_int_mac(int_mac)
    mac = "int32 realigned MACs" if int_mac else "fp32 tile MACs"
    if _qcd_route("dx", (dyq, wq), mac=mac):
        n, k = wq.shape
        dx = gse_matmul_packed_nt(
            _words_2d(dyq), _exps_2d(dyq), wq.mantissa_words, _exps_2d(wq),
            dyq.bits, wq.bits, a_group=dyq.group_size, b_group=wq.group_size,
            bm=_fit_block(int(np.prod(dyq.shape[:-1])), 128),
            bn=_fit(n, 512, dyq.group_size), bk=_fit(k, 128, wq.group_size),
            int_mac=int_mac,
            # plane-prefix views arrive pre-narrowed (words at face width),
            # so the kernel cannot see the truncation — declare it for the
            # int-MAC depth guard (truncated mantissas reach -2^(b-1))
            a_truncated=dyq.exp_shift > 0, b_truncated=wq.exp_shift > 0)
        return dx.reshape(*dyq.shape[:-1], k).astype(compute_dtype)
    dyd = _deq(dyq, compute_dtype)
    wd = _deq(wq, compute_dtype)            # (N, K) == Q(W)^T already
    if f32_out:
        return jnp.matmul(dyd, wd, preferred_element_type=jnp.float32
                          ).astype(compute_dtype)
    return jnp.matmul(dyd, wd)


def qcd_matmul_dw(xq, dyq, *, out_dtype, x_dtype=None, dy_dtype=None,
                  int_mac: bool = False):
    """Backward dW = Q(X)^T @ Q(dY) — contraction over tokens, fp32
    accumulation (the fake-quant path's preferred_element_type), cast to
    ``out_dtype``. Leading dims of both operands are flattened. Kernel
    route: the token-contraction packed matmul (``int_mac``: realigned
    int32 MACs, bounded tier — see qcd_matmul_dx)."""
    int_mac = resolve_int_mac(int_mac)
    mac = "int32 realigned MACs" if int_mac else "fp32 tile MACs"
    if _qcd_route("dw", (xq, dyq), mac=mac):
        k, n = xq.shape[-1], dyq.shape[-1]
        m = int(np.prod(xq.shape[:-1]))
        dw = gse_matmul_packed_tn(
            _words_2d(xq), _exps_2d(xq), _words_2d(dyq), _exps_2d(dyq),
            xq.bits, dyq.bits, a_group=xq.group_size, b_group=dyq.group_size,
            bm=_fit_block(m, 512), bn=_fit(n, 128, dyq.group_size),
            bk=_fit(k, 128, xq.group_size), int_mac=int_mac,
            a_truncated=xq.exp_shift > 0, b_truncated=dyq.exp_shift > 0)
        return dw.astype(out_dtype)
    xd = _deq(xq, x_dtype or out_dtype)
    dyd = _deq(dyq, dy_dtype or out_dtype)
    x2 = xd.reshape(-1, xd.shape[-1])
    dy2 = dyd.reshape(-1, dyd.shape[-1])
    return jnp.matmul(x2.T, dy2, preferred_element_type=jnp.float32
                      ).astype(out_dtype)


def gse_linear_packed(x, w_packed: PackedGSETensor, **block_kw):
    """Linear against a weight held in packed GSE storage: quantize the
    activation on the fly, feed the packed words straight into the fused
    kernel. Only the activation's (tiny) exponents are unpacked host-side;
    the weight mantissas go HBM -> VMEM as b-bit words.

    x: (B, K) float; w_packed: logical (N, K) -> (B, N) fp32.
    """
    bits, group = w_packed.bits, w_packed.group_size
    xm, xe = gse_quantize(x, bits, group)
    we = unpack_exponents(w_packed.exponent_words, w_packed.exponent_shape)
    if w_packed.exp_shift:                  # plane-prefix view compensation
        we = (we.astype(jnp.int32) + w_packed.exp_shift).astype(jnp.int8)
    return gse_matmul_packed(xm, xe, w_packed.mantissa_words, we, bits,
                             group, **block_kw)
