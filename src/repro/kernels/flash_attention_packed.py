"""Pallas TPU kernel: flash attention over a **bit-packed GSE KV cache**,
with tile-local dequantization — the serving hot path that keeps the
paper's storage format resident in HBM during decode.

The K/V operands arrive in the *row-planar* packed layout used by the
packed decode cache (``repro.serve.engine``): every (token, kv-head) row of
``head_dim`` values packs independently into

    words  (..., S, Kv, ceil(D/32) * bits)   uint32  bit-planar mantissas
    exps   (..., S, Kv, D // g)              int8    unbiased shared exps

i.e. the wire chunk layout of ``repro.core.gse`` applied per row, padded to
a whole 32-chunk (``docs/gse-format.md`` §"Row-planar decode layout").
Unlike the flat :class:`~repro.core.gse.PackedGSETensor` stream, one
token's slice is a contiguous word row, so the decode loop can append a
freshly quantized token with a single ``dynamic_update_slice`` — the cache
is never materialized unpacked.

Inside the kernel only the current KV tile is unpacked: the shift/mask body
(``repro.kernels.gse_unpack.unpack_tile``) and exact power-of-two rescale
(``exp2_int``) run on the VMEM-resident (bk, words) tile, feeding the
shared online-softmax tile update of ``repro.kernels.flash_attention``.
HBM traffic and VMEM residency for K/V are therefore ``b + 8/g`` bits per
value (int8 exponents — the row-planar layout trades the 5-bit exponent
packing for appendability); the full fp cache never exists.

Bit-exactness contract: dequantizing a GSE row is exact in fp32 (mantissa
* power-of-two scale), and the tile math is literally the same
``online_softmax_update``/``tile_position_mask`` the dense kernel runs, so
the fused kernel is **bit-identical** to unpack-everything-then-
``flash_attention_pallas`` at the same tiling (the ordered-accumulation
contract; oracle in ``repro.kernels.ref``).

The kernel serves the real decode workload directly:

* ``q_offset`` is a **scalar-prefetch** operand
  (``pltpu.PrefetchScalarGridSpec``): the causal/window mask reads the
  offset from SMEM, so the traced ``cache["index"]`` a decode scan carries
  reaches the kernel without retracing or falling back to jnp.
* **GQA grid**: q arrives folded by kv-head as ``(B*Kv, G, T, D)`` and the
  kernel walks all ``G`` query heads of a group against each packed K/V
  tile — every packed plane row is read (and dequantized) exactly once per
  kv-head, never expanded ``G``-fold in memory.
* Optional **fp tail rows** (``k_tail``/``v_tail``): the current decode
  step's not-yet-quantized k/v, attended after the packed tiles at
  positions ``q_offset + arange(Tt)`` while packed positions ``>=
  q_offset`` are masked. This is the quantize-after-attend append: the
  cache stores the quantized rows, but the current token attends to its
  own k/v at full precision — exactly what the round-trip A/B path sees.

:func:`flash_attention_packed_jnp` is the jnp fallback (interpret/CPU
serving path, plus traced ``is_global`` and ragged S): a ``lax.scan`` over
KV tiles that unpacks one (B, bk, Kv) tile per step — tile-local like the
kernel, same tile order and float sequence (bit-identical at matching
tiles), trace-safe ``q_offset``/``is_global``, ragged sequence lengths via
masked padding, and the same optional fp tail step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gse import (_PACK_CHUNK, DEFAULT_GROUP, effective_group_size,
                            exp2_int, gse_quantize, pack_mantissas,
                            plane_prefix_words, unpack_mantissas)
from repro.kernels.flash_attention import (NEG_INF, attention_scores,
                                           online_softmax_update_scores,
                                           tile_position_mask)
from repro.kernels.gse_matmul import gse_score_tile
from repro.kernels.gse_quant import quantize_tile

DEFAULT_BQ = 256
DEFAULT_BK = 512


# ---------------------------------------------------------------------------
# Row-planar packed KV layout: per-(token, head) rows of head_dim values.
# ---------------------------------------------------------------------------

def kv_row_words(head_dim: int, bits: int) -> int:
    """uint32 words per packed (token, head) row: ceil(D/32) * bits."""
    return -(-head_dim // _PACK_CHUNK) * bits


def kv_row_bits(words_per_row: int, head_dim: int) -> int:
    """Invert :func:`kv_row_words`: recover ``bits`` from the word-plane
    width (static — lets consumers derive the format from array shapes)."""
    chunks = -(-head_dim // _PACK_CHUNK)
    bits, rem = divmod(words_per_row, chunks)
    if rem or not 2 <= bits <= 8:
        raise ValueError(f"words/row {words_per_row} is not a packed row of "
                         f"head_dim {head_dim}")
    return bits


def quant_pack_kv_rows(x: jax.Array, bits: int, group: int = DEFAULT_GROUP,
                       interpret: bool = True, int32_shifts: bool = False):
    """Quantize + pack ``x`` (..., D) into row-planar KV planes.

    Returns (words (..., ceil(D/32)*bits) uint32, exps (..., D//g) int8)
    with g = largest divisor of D that is <= ``group``. 32-aligned head
    dims run the fused quantize+pack Pallas kernel (one VMEM pass, no int8
    intermediate); ragged dims take the jnp two-step path whose words are
    bit-identical (``pack_mantissas`` zero-pads the final chunk).
    """
    d = x.shape[-1]
    g = effective_group_size(d, group)
    if d % _PACK_CHUNK == 0:
        from repro.kernels.gse_quant_pack import gse_quant_pack_pallas
        words, exps = gse_quant_pack_pallas(
            x.reshape(-1, d), bits, g, interpret=interpret,
            int32_shifts=int32_shifts)
        return (words.reshape(*x.shape[:-1], kv_row_words(d, bits)),
                exps.reshape(*x.shape[:-1], d // g))
    t = gse_quantize(x, bits, g)
    return (pack_mantissas(t.mantissa, bits, int32_shifts=int32_shifts),
            t.exponent)


def unpack_kv_row_mantissas(words: jax.Array, head_dim: int,
                            int32_shifts: bool = False):
    """Row-planar word planes -> int8 mantissas (..., D), NO rescale — the
    integer-MAC score path consumes mantissas and exponents separately
    (the rank-1 ``2^(eq+ek)`` rescale happens after the int32 MAC)."""
    d32 = -(-head_dim // _PACK_CHUNK) * _PACK_CHUNK
    bits = kv_row_bits(words.shape[-1], head_dim)
    return unpack_mantissas(words, bits, d32,
                            int32_shifts=int32_shifts)[..., :head_dim]


def dequant_q_rows(qm: jax.Array, qe: jax.Array, group: int):
    """Exact fp32 dequant of in-flight quantized q rows (fp-valued
    mantissas (..., D) x exponents (..., D/G)) — the tail columns of the
    int-MAC score mode attend through Q(q) so packed and tail scores see
    the same query values."""
    ng = qe.shape[-1]
    scale = exp2_int(qe.astype(jnp.int32))
    vals = qm.astype(jnp.float32).reshape(*qm.shape[:-1], ng, group)
    return (vals * scale[..., None]).reshape(qm.shape)


def dequant_kv_rows(words: jax.Array, exps: jax.Array, head_dim: int,
                    dtype=jnp.float32, int32_shifts: bool = False,
                    trunc=None):
    """Row-planar planes -> values (..., D). Pure jnp shift/mask + exact
    power-of-two rescale; runs host-side and on VMEM tiles inside the
    kernel (the single definition of the row dequant).

    ``trunc`` (traced int32, broadcastable against the row axes) reads the
    rows at a *dynamically* narrower width: mantissas floor-shift right by
    ``trunc`` and exponents compensate by ``+trunc`` — bit-identical to
    decoding a static ``with_bits`` plane-prefix view at ``bits - trunc``
    (the ``(u - 2^(s-1)) >> t == (u >> t) - 2^(b-1)`` identity), but usable
    when different rows of one fused call read different widths (the
    mixed-``kv_bits`` decode lanes of the serving engine). Unlike the
    static prefix it cannot skip HBM traffic for the dropped planes."""
    d32 = -(-head_dim // _PACK_CHUNK) * _PACK_CHUNK
    bits = kv_row_bits(words.shape[-1], head_dim)
    m = unpack_mantissas(words, bits, d32,
                         int32_shifts=int32_shifts)[..., :head_dim]
    e32 = exps.astype(jnp.int32)
    if trunc is not None:
        t = jnp.asarray(trunc, jnp.int32)
        m = jnp.right_shift(m.astype(jnp.int32), t)   # arithmetic on int32
        e32 = e32 + t
    g = head_dim // exps.shape[-1]
    scale = exp2_int(e32)                             # exact 2^e, fp32
    vals = m.astype(jnp.float32).reshape(*m.shape[:-1], exps.shape[-1], g)
    return (vals * scale[..., None]).reshape(*m.shape[:-1],
                                             head_dim).astype(dtype)


# ---------------------------------------------------------------------------
# Pallas kernel: (BKv, G, T, D) q against (BKv, S, ·) packed planes.
# q_offset rides in SMEM (scalar prefetch); optional fp tail rows close the
# quantize-after-attend append (decode).
# ---------------------------------------------------------------------------

def _group_mask(mask, groups: int):
    """Repeat a (bq, bk) tile mask over the q-head group axis -> (G*bq, bk).
    All heads of a group share positions, so the mask is position-only."""
    if mask is None or groups == 1:
        return mask
    return jnp.broadcast_to(mask[None], (groups, *mask.shape)).reshape(
        groups * mask.shape[0], mask.shape[1])


def tail_position_mask(bq: int, tail_len: int, qi, causal: bool,
                       window: int, q_offset, is_global=None):
    """(bq, tail_len) mask for the fp tail rows, which sit at absolute
    positions ``q_offset + arange(tail_len)`` (the current decode step's
    own tokens). Shared by the kernel and the jnp fallback; a per-sequence
    ``(B,)`` offset broadcasts to a (B, bq, tail_len) mask."""
    off = jnp.asarray(q_offset)
    if off.ndim:
        off = off[..., None, None]
    qpos = off + qi * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, tail_len), 0)
    tpos = off + jax.lax.broadcasted_iota(
        jnp.int32, (bq, tail_len), 1)
    mask = jnp.ones(qpos.shape, jnp.bool_)
    if causal:
        mask = mask & (tpos <= qpos)
    if window:
        local = tpos > qpos - window
        mask = mask & (local if is_global is None else (local | is_global))
    return mask


def _kv_tile(ref, paged: bool):
    """One packed K/V exponent tile from its block ref: (1, bk, G) planar
    blocks, or (1, page, 1, G) page blocks (paged grid — the kv-head axis
    sits after the page-row axis in the pool layout)."""
    return ref[0][:, 0] if paged else ref[0]


def _kv_words_tile(ref, paged: bool):
    """One packed K/V *word* tile from its plane-axis block ref —
    (1, bk, ab, C) planar or (1, page, 1, ab, C) paged — flattened back to
    the contiguous plane-major (rows, ab*C) row stream the dequant
    expects. The plane axis is how narrow reads skip HBM traffic: the
    BlockSpec pins it to the first ``active_bits`` planes, so the dropped
    planes of a prefix read are never fetched."""
    t = ref[0][:, 0] if paged else ref[0]
    return t.reshape(t.shape[0], t.shape[1] * t.shape[2])


def _flash_packed_kernel(qoff_ref, q_ref, kw_ref, ke_ref, vw_ref, ve_ref,
                         *rest, head_dim: int, groups: int, bq: int,
                         bk: int, k_steps: int, tail_len: int, causal: bool,
                         window: int, scale: float, int32_shifts: bool,
                         int_mac: bool, bits: int, paged: bool = False,
                         trunc_ref=None, has_trunc: bool = False):
    if tail_len:
        kt_ref, vt_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    # SMEM per-sequence offset vector (traced decode): each (b, kv) program
    # reads its own scalar — the scalar-offset case is the same vector with
    # one value broadcast, so the kernel body is offset-layout-agnostic
    q_offset = qoff_ref[pl.program_id(0)]
    # per-sequence dynamic truncation (mixed-precision decode lanes): each
    # (b, kv) program reads its own plane-shift scalar from SMEM and the
    # dequant floor-shifts mantissas / compensates exponents in VMEM —
    # bit-identical to a static plane-prefix read at (bits - trunc)
    trunc = trunc_ref[pl.program_id(0)] if has_trunc else None

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # tile-local dequant: only this (bk, D) K/V tile ever exists unpacked,
    # and only in VMEM — HBM holds b-bit words + int8 exponents
    v = dequant_kv_rows(_kv_words_tile(vw_ref, paged),
                        _kv_tile(ve_ref, paged), head_dim,
                        int32_shifts=int32_shifts, trunc=trunc)
    q = q_ref[0].reshape(groups * bq, head_dim).astype(jnp.float32)
    if int_mac:
        # exact tier: quantize q once per tile at the cache's bits/group,
        # keep K as raw int8 mantissas, and run the score GEMM as the
        # forward kernel's group-batched int8 MXU MAC + rank-1 rescale
        # (head_dim is the grouping axis). The V/PV GEMM stays fp32.
        km = unpack_kv_row_mantissas(_kv_words_tile(kw_ref, paged),
                                     head_dim,
                                     int32_shifts=int32_shifts)  # (bk, D)
        g_sz = head_dim // ke_ref.shape[-1]
        qm, qe = quantize_tile(q, bits, g_sz)
        qm8, qe8 = qm.astype(jnp.int8), qe.astype(jnp.int8)

        def packed_scores():
            return gse_score_tile(qm8, qe8, km, _kv_tile(ke_ref, paged),
                                  group=g_sz) * scale
        # tail columns (when present) attend through the dequantized Q(q)
        # in fp32, as their own update — see the int_mac tail branch below
    else:
        k = dequant_kv_rows(_kv_words_tile(kw_ref, paged),
                            _kv_tile(ke_ref, paged), head_dim,
                            int32_shifts=int32_shifts,
                            trunc=trunc)                    # (bk, D) fp32

        def packed_scores():
            return attention_scores(q, k, scale)

        def merged_scores(kt):
            return attention_scores(q, jnp.concatenate([k, kt]), scale)
    mask = tile_position_mask(bq, bk, qi, ki, causal, window, q_offset)
    if tail_len:
        # tail rows own positions >= q_offset; the packed planes only the
        # history (rows there may hold the already-quantized append)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        hist = kpos < q_offset
        mask = hist if mask is None else mask & hist

        if int_mac:
            # int mode runs the packed tile and the fp tail as TWO
            # sequential fixed-shape updates. Merging them (the fp-mode
            # shape below) concatenates two separately-produced score
            # blocks, and XLA rounds the downstream fp32 recurrence
            # differently per compilation of that concat graph (ulp-level
            # mul+add fusion) — two plain updates are the one structure
            # the kernel and the jnp fallback reproduce bitwise.
            online_softmax_update_scores(packed_scores(), v,
                                         _group_mask(mask, groups),
                                         m_scr, l_scr, acc_scr)

            @pl.when(ki == k_steps - 1)
            def _tail_update():
                kt = kt_ref[0].astype(jnp.float32)          # (Tt, D)
                vt = vt_ref[0].astype(jnp.float32)
                tmask = tail_position_mask(bq, tail_len, qi, causal,
                                           window, q_offset)
                s_tail = attention_scores(dequant_q_rows(qm, qe, g_sz),
                                          kt, scale)
                online_softmax_update_scores(s_tail, vt,
                                             _group_mask(tmask, groups),
                                             m_scr, l_scr, acc_scr)
        else:
            # fp mode: the fp tail joins the LAST packed tile's update —
            # ONE softmax update over bk + Tt score columns, matching the
            # fallback's merged single-GEMM last step bit-for-bit
            @pl.when(ki < k_steps - 1)
            def _update():
                online_softmax_update_scores(packed_scores(), v,
                                             _group_mask(mask, groups),
                                             m_scr, l_scr, acc_scr)

            @pl.when(ki == k_steps - 1)
            def _last_with_tail():
                kt = kt_ref[0].astype(jnp.float32)          # (Tt, D)
                vt = vt_ref[0].astype(jnp.float32)
                tmask = tail_position_mask(bq, tail_len, qi, causal,
                                           window, q_offset)
                online_softmax_update_scores(
                    merged_scores(kt), jnp.concatenate([v, vt]),
                    _group_mask(jnp.concatenate([mask, tmask], axis=1),
                                groups),
                    m_scr, l_scr, acc_scr)
    else:
        online_softmax_update_scores(packed_scores(), v,
                                     _group_mask(mask, groups), m_scr,
                                     l_scr, acc_scr)

    @pl.when(ki == k_steps - 1)
    def _store():
        o = acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = o.reshape(groups, bq, head_dim).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk",
                                    "interpret", "int32_shifts", "int_mac",
                                    "kv_active_bits"))
def flash_attention_packed_pallas(q, k_words, k_exp, v_words, v_exp,
                                  causal: bool = True, window: int = 0,
                                  q_offset=0, bq: int = DEFAULT_BQ,
                                  bk: int = DEFAULT_BK, k_tail=None,
                                  v_tail=None, interpret: bool = True,
                                  int32_shifts: bool = False,
                                  int_mac: bool = False,
                                  kv_active_bits: int | None = None):
    """q (BH, T, D) float (MHA) or (B*Kv, G, T, D) (GQA, folded by
    kv-head); k/v planes (BH|B*Kv, S, W) uint32 + (·, S, G) int8
    (row-planar packed layout) -> same leading layout as q.

    ``q_offset`` may be a python int, a traced scalar (the decode scan's
    ``cache["index"]``), **or a per-row vector** matching q's leading axis
    (ragged continuous-batching decode — one offset per (b, kv) program):
    it is threaded into the kernel via scalar prefetch and each program
    reads its own entry from SMEM. On the GQA grid the q block walks its
    whole head group against each packed K/V tile, so every plane row is
    dequantized once per kv-head (never expanded).
    ``k_tail``/``v_tail`` (·, Tt, D) fp rows, when given, are attended
    *after* the packed tiles at positions ``q_offset + arange(Tt)`` while
    packed positions ``>= q_offset`` are masked — the quantize-after-attend
    decode append.

    ``int_mac=True`` runs the score GEMM on the MXU in int8: q is
    quantized in-kernel to the cache's bits/group (head_dim is the
    grouping axis, so the forward matmul's exact rank-1-rescale recipe
    applies — exact tier, bit-equal to the grouped fp32 score oracle);
    tail columns attend through the dequantized Q(q) in fp32.

    ``kv_active_bits`` (static, default: the cache's stored width) reads
    the plane-prefix view: the K/V BlockSpecs pin the plane axis to the
    first ``active_bits`` planes of every row, so the dropped planes are
    never fetched from HBM, and the tile math sees the floor-truncated
    mantissas against wrapper-compensated exponents — bit-identical to
    attending a ``with_bits(active_bits)`` re-pack of the cache.
    """
    if q.ndim == 3:                           # MHA layout: group size 1
        o = flash_attention_packed_pallas(
            q[:, None], k_words, k_exp, v_words, v_exp, causal=causal,
            window=window, q_offset=q_offset, bq=bq, bk=bk, k_tail=k_tail,
            v_tail=v_tail, interpret=interpret, int32_shifts=int32_shifts,
            int_mac=int_mac, kv_active_bits=kv_active_bits)
        return o[:, 0]
    bkv, groups, t, d = q.shape
    s_len = k_words.shape[1]
    wpr, gexp = k_words.shape[-1], k_exp.shape[-1]
    bits = kv_row_bits(wpr, d)
    assert v_words.shape[-1] == wpr, (
        "packed row width mismatch", k_words.shape, v_words.shape, d)
    ab = bits if kv_active_bits is None else kv_active_bits
    if not 2 <= ab <= bits:
        raise ValueError(f"kv_active_bits {ab} outside [2, bits={bits}]")
    chunks = wpr // bits
    if ab != bits:
        # fold the view's exponent compensation once, outside the kernel —
        # the tile bodies stay width-agnostic (max 15 + 6 fits int8)
        k_exp = (k_exp.astype(jnp.int32) + (bits - ab)).astype(jnp.int8)
        v_exp = (v_exp.astype(jnp.int32) + (bits - ab)).astype(jnp.int8)
    bq = min(bq, t)
    bk = min(bk, s_len)
    assert t % bq == 0 and s_len % bk == 0, (t, bq, s_len, bk)
    k_steps = s_len // bk
    tail_len = 0 if k_tail is None else k_tail.shape[1]
    grid = (bkv, t // bq, k_steps)
    kernel = functools.partial(
        _flash_packed_kernel, head_dim=d, groups=groups, bq=bq, bk=bk,
        k_steps=k_steps, tail_len=tail_len, causal=causal, window=window,
        scale=d ** -0.5, int32_shifts=int32_shifts, int_mac=int_mac,
        bits=ab)
    from jax.experimental.pallas import tpu as pltpu
    # plane-axis views of the word streams: blocks pin the plane axis to
    # the first `ab` planes, so a prefix read moves ab/bits of the bytes
    kw4 = k_words.reshape(bkv, s_len, bits, chunks)
    vw4 = v_words.reshape(bkv, s_len, bits, chunks)
    in_specs = [
        pl.BlockSpec((1, groups, bq, d), lambda b, i, j, off: (b, 0, i, 0)),
        pl.BlockSpec((1, bk, ab, chunks), lambda b, i, j, off: (b, j, 0, 0)),
        pl.BlockSpec((1, bk, gexp), lambda b, i, j, off: (b, j, 0)),
        pl.BlockSpec((1, bk, ab, chunks), lambda b, i, j, off: (b, j, 0, 0)),
        pl.BlockSpec((1, bk, gexp), lambda b, i, j, off: (b, j, 0)),
    ]
    operands = [q, kw4, k_exp, vw4, v_exp]
    if tail_len:
        in_specs += [
            pl.BlockSpec((1, tail_len, d), lambda b, i, j, off: (b, 0, 0)),
            pl.BlockSpec((1, tail_len, d), lambda b, i, j, off: (b, 0, 0)),
        ]
        operands += [k_tail, v_tail]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, groups, bq, d),
                               lambda b, i, j, off: (b, 0, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((groups * bq, 1), jnp.float32),
            pltpu.VMEM((groups * bq, 1), jnp.float32),
            pltpu.VMEM((groups * bq, d), jnp.float32),
        ],
    )
    # scalar offsets broadcast to the per-program vector layout: one SMEM
    # entry per (b, kv) row, read by program id — one kernel body serves
    # both the shared-offset and the ragged per-sequence decode
    off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32).reshape(-1),
                           (bkv,))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bkv, groups, t, d), q.dtype),
        interpret=interpret,
    )(off, *operands)


# ---------------------------------------------------------------------------
# Paged variant: the packed planes live in a fixed-size page pool
# (P, page, Kv, ·) and each sequence's logical KV order is its page-table
# row. The kernel grid walks logical pages; the K/V block index maps read
# the page table from SMEM (scalar prefetch) to fetch each sequence's
# physical page — the pool is never gathered or expanded in HBM.
# ---------------------------------------------------------------------------


def _flash_paged_kernel(pt_ref, qoff_ref, trunc_ref, *rest, **kw):
    """Page-pool kernel body: the page table ref is consumed by the K/V
    BlockSpec index maps (physical page selection); the per-sequence
    truncation vector rides the same SMEM lane as the offsets (mixed-
    precision decode lanes); the softmax body is the planar kernel's,
    walking logical pages as its KV tiles."""
    del pt_ref
    return _flash_packed_kernel(qoff_ref, *rest, paged=True,
                                trunc_ref=trunc_ref, **kw)


def gather_pages(pool, page_table):
    """Materialize the logical (B, maxp*page, Kv, ·) plane view of a paged
    pool (P, page, Kv, ·) via the page table (B, maxp). The gather moves
    **packed** words/exponents only (uint32/int8 — never dequantized fp);
    the jnp fallback route and the oracles attend this view with the
    planar tile math."""
    g = pool[page_table]                      # (B, maxp, page, Kv, ·)
    return g.reshape(page_table.shape[0], -1, *pool.shape[2:])


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "interpret",
                                    "int32_shifts", "int_mac",
                                    "kv_active_bits"))
def flash_attention_paged_pallas(q, k_words, k_exp, v_words, v_exp,
                                 page_table, q_offset=0,
                                 causal: bool = True, window: int = 0,
                                 bq: int = DEFAULT_BQ, k_tail=None,
                                 v_tail=None, interpret: bool = True,
                                 int32_shifts: bool = False,
                                 int_mac: bool = False,
                                 kv_active_bits: int | None = None,
                                 kv_trunc=None):
    """q (BH, T, D) (MHA) or (B*Kv, G, T, D) (GQA, folded by kv-head);
    K/V pools (P, page, Kv, ·) in the paged row-planar layout
    (docs/gse-format.md §4: the S axis of the planar planes carved into
    fixed pages); page_table (B, maxp) int32 of physical page ids ->
    same leading layout as q.

    Two scalar-prefetch operands ride in SMEM: the page table (the K/V
    block index maps resolve ``pt[b, j]`` per grid step, so the kernel
    walks each sequence's pages in logical order without any gather) and
    the per-sequence ``q_offset`` vector (per-program position masks —
    lengths are ragged across the batch). The KV tile size is the page
    size; unallocated logical pages point at the permanent zero page and
    their columns are masked per-sequence (``kpos < q_offset`` under a
    tail, causal otherwise) — exact no-ops in the online softmax. Tile
    dequant, GQA walk, fp tails and ``int_mac`` are the planar kernel's
    (shared body) — bit-exact vs the gather-then-planar fallback at
    ``k_chunk == page``.

    Progressive precision: ``kv_active_bits`` (static) reads the whole
    pool at a narrower width via the plane-prefix BlockSpec (dropped
    planes never leave HBM); ``kv_trunc`` (traced — an int or per-sequence
    (B,) int32 vector of *extra plane shifts below the active width*)
    rides the scalar-prefetch lane beside the page table and offsets, so
    one fused decode block serves lanes reading the same pool at
    different effective widths (sequence i decodes at ``active_bits -
    kv_trunc[i]``). ``kv_trunc`` is incompatible with ``int_mac`` (the
    int8 score MAC would need per-lane requantized q).
    """
    if q.ndim == 3:                           # MHA layout: group size 1
        o = flash_attention_paged_pallas(
            q[:, None], k_words, k_exp, v_words, v_exp, page_table,
            q_offset=q_offset, causal=causal, window=window, bq=bq,
            k_tail=k_tail, v_tail=v_tail, interpret=interpret,
            int32_shifts=int32_shifts, int_mac=int_mac,
            kv_active_bits=kv_active_bits, kv_trunc=kv_trunc)
        return o[:, 0]
    bkv, groups, t, d = q.shape
    _, page, kv_heads, wpr = k_words.shape
    gexp = k_exp.shape[-1]
    nseq, maxp = page_table.shape
    assert nseq * kv_heads == bkv, (page_table.shape, kv_heads, bkv)
    bits = kv_row_bits(wpr, d)
    assert v_words.shape[-1] == wpr, (
        "packed row width mismatch", k_words.shape, v_words.shape, d)
    ab = bits if kv_active_bits is None else kv_active_bits
    if not 2 <= ab <= bits:
        raise ValueError(f"kv_active_bits {ab} outside [2, bits={bits}]")
    has_trunc = kv_trunc is not None
    if has_trunc and int_mac:
        raise ValueError("int_mac with traced kv_trunc is unsupported — "
                         "use a static kv_active_bits instead")
    chunks = wpr // bits
    if ab != bits:
        k_exp = (k_exp.astype(jnp.int32) + (bits - ab)).astype(jnp.int8)
        v_exp = (v_exp.astype(jnp.int32) + (bits - ab)).astype(jnp.int8)
    bq = min(bq, t)
    assert t % bq == 0, (t, bq)
    tail_len = 0 if k_tail is None else k_tail.shape[1]
    grid = (bkv, t // bq, maxp)
    kernel = functools.partial(
        _flash_paged_kernel, head_dim=d, groups=groups, bq=bq, bk=page,
        k_steps=maxp, tail_len=tail_len, causal=causal, window=window,
        scale=d ** -0.5, int32_shifts=int32_shifts, int_mac=int_mac,
        bits=ab, has_trunc=has_trunc)
    from jax.experimental.pallas import tpu as pltpu

    def kv_map(b, i, j, pt, off, tr):         # physical page of logical j
        return (pt[b // kv_heads, j], 0, b % kv_heads, 0, 0)

    # plane-axis pool views: page blocks pin the plane axis to the first
    # `ab` planes (zero-copy narrow read of the shared pool)
    kw5 = k_words.reshape(-1, page, kv_heads, bits, chunks)
    vw5 = v_words.reshape(-1, page, kv_heads, bits, chunks)
    in_specs = [
        pl.BlockSpec((1, groups, bq, d),
                     lambda b, i, j, pt, off, tr: (b, 0, i, 0)),
        pl.BlockSpec((1, page, 1, ab, chunks), kv_map),
        pl.BlockSpec((1, page, 1, gexp),
                     lambda b, i, j, pt, off, tr:
                     (pt[b // kv_heads, j], 0, b % kv_heads, 0)),
        pl.BlockSpec((1, page, 1, ab, chunks), kv_map),
        pl.BlockSpec((1, page, 1, gexp),
                     lambda b, i, j, pt, off, tr:
                     (pt[b // kv_heads, j], 0, b % kv_heads, 0)),
    ]
    operands = [q, kw5, k_exp, vw5, v_exp]
    if tail_len:
        in_specs += [
            pl.BlockSpec((1, tail_len, d),
                         lambda b, i, j, pt, off, tr: (b, 0, 0)),
            pl.BlockSpec((1, tail_len, d),
                         lambda b, i, j, pt, off, tr: (b, 0, 0)),
        ]
        operands += [k_tail, v_tail]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, groups, bq, d),
                               lambda b, i, j, pt, off, tr: (b, 0, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((groups * bq, 1), jnp.float32),
            pltpu.VMEM((groups * bq, 1), jnp.float32),
            pltpu.VMEM((groups * bq, d), jnp.float32),
        ],
    )
    pt = jnp.asarray(page_table, jnp.int32)
    off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32).reshape(-1),
                           (bkv,))
    # per-sequence trunc vector -> one SMEM entry per (b, kv) program (the
    # offset vector's layout); scalar/None broadcasts
    trv = jnp.asarray(0 if kv_trunc is None else kv_trunc,
                      jnp.int32).reshape(-1)
    if trv.shape[0] == nseq and kv_heads > 1:
        trv = jnp.repeat(trv, kv_heads)
    tr = jnp.broadcast_to(trv, (bkv,))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bkv, groups, t, d), q.dtype),
        interpret=interpret,
    )(pt, off, tr, *operands)


# ---------------------------------------------------------------------------
# GQA-aware jnp fallback: the interpret/CPU decode path. Tile-local like
# the kernel (lax.scan over KV tiles, one tile unpacked per step).
# ---------------------------------------------------------------------------

def _pad_seq(x, pad):
    return jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "k_chunk",
                                    "int32_shifts", "int_mac",
                                    "kv_active_bits"))
def flash_attention_packed_jnp(q, k_words, k_exp, v_words, v_exp,
                               causal: bool = True, window: int = 0,
                               q_offset=0, is_global=None,
                               k_tail=None, v_tail=None,
                               k_chunk: int = DEFAULT_BK,
                               int32_shifts: bool = False,
                               int_mac: bool = False,
                               kv_active_bits: int | None = None,
                               kv_trunc=None):
    """q (B, T, H, D); planes (B, S, Kv, ·) -> (B, T, H, D).

    Per scan step exactly one (B, kc, Kv, D) K/V tile is dequantized —
    peak live unpacked KV is one tile, matching the kernel's VMEM
    residency claim. ``q_offset`` and ``is_global`` may be traced (decode);
    ragged S pads to a whole tile with positions masked by ``kpos < S``.
    ``k_tail``/``v_tail`` (B, Tt, Kv, D) fp rows run one extra
    online-softmax step after the packed tiles, at positions ``q_offset +
    arange(Tt)``, with packed positions ``>= q_offset`` masked — the same
    quantize-after-attend semantics as the kernel.

    ``int_mac=True`` replays the kernel's integer-MAC score recipe (q
    quantized once to the cache's bits/group, per-group int MAC + rank-1
    rescale summed in ascending group order, fp32 tail against Q(q)) —
    bit-identical to the kernel's int mode at matching tiles.

    ``kv_active_bits`` (static) reads the plane-prefix view — the same
    narrowed words/compensated exponents the kernel's prefix BlockSpec
    fetches. ``kv_trunc`` (traced (B,) int32, fp mode only) shifts each
    sequence's rows by extra planes at dequant time — the mixed-precision
    decode lanes.
    """
    b, t, h, d = q.shape
    s_len, kv = k_words.shape[1], k_words.shape[2]
    g = h // kv
    stored = kv_row_bits(k_words.shape[-1], d)
    if kv_active_bits is not None and kv_active_bits != stored:
        if not 2 <= kv_active_bits <= stored:
            raise ValueError(f"kv_active_bits {kv_active_bits} outside "
                             f"[2, bits={stored}]")
        sh = stored - kv_active_bits
        k_words = plane_prefix_words(k_words, stored, kv_active_bits)
        v_words = plane_prefix_words(v_words, stored, kv_active_bits)
        k_exp = (k_exp.astype(jnp.int32) + sh).astype(jnp.int8)
        v_exp = (v_exp.astype(jnp.int32) + sh).astype(jnp.int8)
    if kv_trunc is not None:
        if int_mac:
            raise ValueError("int_mac with traced kv_trunc is unsupported "
                             "— use a static kv_active_bits instead")
        kv_trunc = jnp.asarray(kv_trunc, jnp.int32).reshape(
            -1, 1, 1, 1)                      # (B,1,1,1) over (B,kc,Kv,D)
    kc = min(k_chunk, s_len)
    pad = (-s_len) % kc
    ragged = pad > 0
    if ragged:
        k_words, k_exp = _pad_seq(k_words, pad), _pad_seq(k_exp, pad)
        v_words, v_exp = _pad_seq(v_words, pad), _pad_seq(v_exp, pad)
    nk = (s_len + pad) // kc

    def chunked(x):                       # (B, nk*kc, Kv, ·) -> scan xs
        return x.reshape(b, nk, kc, *x.shape[2:]).transpose(
            1, 0, 2, *range(3, x.ndim + 1))

    xs = (chunked(k_words), chunked(k_exp), chunked(v_words),
          chunked(v_exp), jnp.arange(nk))
    qg = q.reshape(b, t, kv, g, d).astype(jnp.float32)
    qoff = jnp.asarray(q_offset, jnp.int32)
    # scalar offset -> (T,) positions / 2-D masks; per-sequence (B,) vector
    # -> (B, T) positions / 3-D masks (ragged batches differ per row)
    qpos = qoff[..., None] + jnp.arange(t) if qoff.ndim else \
        qoff + jnp.arange(t)
    has_tail = k_tail is not None
    scale = d ** -0.5

    if int_mac:
        # quantize q ONCE at the cache's bits/group (same quantize_tile as
        # the kernel); packed scores run the per-group int MAC + rank-1
        # rescale in ascending group order, tail scores the fp32 GEMM
        # against the dequantized Q(q) — the kernel's exact float sequence.
        kb_bits = kv_row_bits(k_words.shape[-1], d)
        g_sz = d // k_exp.shape[-1]
        ngr = d // g_sz
        qm, qe = quantize_tile(qg.reshape(-1, d), kb_bits, g_sz)
        qdq = dequant_q_rows(qm, qe, g_sz).reshape(b, t, kv, g, d)
        qmg = qm.astype(jnp.int32).reshape(b, t, kv, g, ngr, g_sz)
        sq = jnp.moveaxis(exp2_int(qe.astype(jnp.int32))
                          .reshape(b, t, kv, g, ngr), -1, 0)  # (n,b,t,kv,g)
        sqn = sq.transpose(0, 1, 3, 4, 2)                     # (n,b,kv,g,t)

        def packed_scores(kwb, keb):
            km = unpack_kv_row_mantissas(
                kwb, d, int32_shifts=int32_shifts)      # (B, kc, Kv, D)
            kmg = km.astype(jnp.int32).reshape(b, -1, kv, ngr, g_sz)
            prod = jnp.einsum("btkgnc,bsknc->nbkgts", qmg, kmg)   # int32
            sk = jnp.moveaxis(exp2_int(keb.astype(jnp.int32)), -1, 0)
            skn = sk.transpose(0, 1, 3, 2)                    # (n,b,kv,s)
            scaled = (prod.astype(jnp.float32) * sqn[..., None]
                      * skn[:, :, :, None, None, :])
            acc = jnp.zeros(scaled.shape[1:], jnp.float32)
            for gi in range(ngr):           # ordered group sum (contract)
                acc = acc + scaled[gi]
            return acc * scale

        def tail_scores(ktail):
            # fp32 tail GEMM against Q(q) — its own softmax update, the
            # same split-step structure as the kernel's int_mac tail
            return jnp.einsum("btkgd,bskd->bkgts", qdq,
                              ktail.astype(jnp.float32),
                              preferred_element_type=jnp.float32) * scale
    else:
        def packed_scores(kwb, keb):
            kblk = dequant_kv_rows(kwb, keb, d, int32_shifts=int32_shifts,
                                   trunc=kv_trunc)
            return jnp.einsum("btkgd,bskd->bkgts", qg, kblk,
                              preferred_element_type=jnp.float32) * scale

        def merged_scores(kwb, keb, ktail):
            # one score GEMM over kc + Tt columns (the kernel's merged
            # last step — same float sequence)
            kblk = dequant_kv_rows(kwb, keb, d, int32_shifts=int32_shifts,
                                   trunc=kv_trunc)
            kcat = jnp.concatenate([kblk, ktail.astype(jnp.float32)],
                                   axis=1)
            return jnp.einsum("btkgd,bskd->bkgts", qg, kcat,
                              preferred_element_type=jnp.float32) * scale

    def tile_update(carry, sblk, vblk, mask):
        """One online-softmax tile from precomputed scores (B, Kv, G, T, S)
        against fp V (B, kc, Kv, D) — the single float sequence shared by
        the packed tiles and the tail, whichever MAC produced the scores."""
        m_prev, l_prev, acc = carry
        sblk = jnp.where(_bc(mask), sblk, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(sblk, axis=-1))
        p = jnp.exp(sblk - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgts,bskd->bkgtd", p, vblk,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc)

    def _bc(mask):                        # (T,S)->(1,1,1,T,S), (B,T,S)->+kv/g
        return (mask[None, None, None] if mask.ndim == 2
                else mask[:, None, None])

    def tile_mask(kpos):
        # same structural mask as models.attention.block_mask, plus the
        # ragged-tail validity term (padded rows never win the softmax)
        # and, under a tail, the history term (packed rows at the current
        # step's positions may hold the already-quantized append)
        qp = qpos[..., :, None]           # (T,1) or (B,T,1)
        kp = kpos[None, :]
        mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
        if causal:
            mask = mask & (kp <= qp)
        if window:
            local = kp > (qp - window)
            mask = mask & (local if is_global is None
                           else (local | is_global))
        if ragged:
            mask = mask & (kp < s_len)
        if has_tail:
            mask = mask & (kp < (qoff[..., None, None] if qoff.ndim
                                 else qoff))
        return mask

    def k_step(carry, inp):
        kwb, keb, vwb, veb, ki = inp
        vblk = dequant_kv_rows(vwb, veb, d, int32_shifts=int32_shifts,
                               trunc=kv_trunc)
        return tile_update(carry, packed_scores(kwb, keb), vblk,
                           tile_mask(ki * kc + jnp.arange(kc))), None

    m0 = jnp.full((b, kv, g, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, t), jnp.float32)
    a0 = jnp.zeros((b, kv, g, t, d), jnp.float32)
    # fp mode with a tail: the last packed tile and the fp tail merge into
    # ONE softmax update over kc + Tt score columns — the same m/l/acc
    # recurrence as the kernel's merged last step. int mode instead scans
    # ALL packed tiles and runs the tail as its own update (the kernel's
    # split-step int_mac structure — see its comment on concat rounding).
    n_scan = nk if (not has_tail or int_mac) else nk - 1
    carry, _ = jax.lax.scan(k_step, (m0, l0, a0),
                            jax.tree.map(lambda x: x[:n_scan], xs))
    if has_tail:
        tmask = tail_position_mask(t, k_tail.shape[1], 0, causal, window,
                                   qoff, is_global)
        if int_mac:
            carry = tile_update(carry, tail_scores(k_tail),
                                v_tail.astype(jnp.float32), tmask)
        else:
            kwb, keb, vwb, veb = (x[nk - 1] for x in xs[:4])
            vblk = dequant_kv_rows(vwb, veb, d, int32_shifts=int32_shifts,
                                   trunc=kv_trunc)
            carry = tile_update(
                carry,
                merged_scores(kwb, keb, k_tail),
                jnp.concatenate([vblk, v_tail.astype(jnp.float32)], axis=1),
                jnp.concatenate([tile_mask((nk - 1) * kc + jnp.arange(kc)),
                                 tmask], axis=-1))
    _, l_f, acc = carry
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    # (B, KV, G, T, D) -> (B, T, KV, G, D) -> (B, T, H, D)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, t, h, d).astype(q.dtype)
