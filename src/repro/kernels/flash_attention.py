"""Pallas TPU kernel: flash attention forward (online softmax over KV
blocks) — the attention hot path at 32k prefill. VMEM-resident running
(max, sum, acc) scratch per query block; causal / sliding-window masks are
computed from positions inside the kernel (no (T, S) mask in HBM).

The jnp reference is ``repro.models.attention.flash_attention_ref`` /
``direct_attention``; the training path uses the custom-VJP jnp
implementation (backward kernel: recompute-based, see DESIGN §4 note).

Layout: q (BH, T, D); k/v (BH, S, D) — GQA callers expand KV heads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def tile_position_mask(bq: int, bk: int, qi, ki, causal: bool, window: int,
                       q_offset):
    """(bq, bk) bool mask for the (qi, ki) tile, or None if unmasked.

    Positions are built in-kernel from the tile indices (no (T, S) mask in
    HBM). Shared by the dense and the packed-KV flash kernels so both carry
    the identical masking definition.
    """
    if not (causal or window):
        return None
    qpos = q_offset + qi * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if window:
        mask = mask & (kpos > qpos - window)
    return mask


def attention_scores(q, k, scale: float):
    """The fp32 score GEMM of one tile: q (bq, D) x k (bk, D) -> (bq, bk).
    Factored out of :func:`online_softmax_update` so the packed-KV kernel
    can swap in the integer-MAC score path while the softmax recurrence
    stays the single shared definition."""
    return jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32) * scale


def online_softmax_update(q, k, v, mask, m_scr, l_scr, acc_scr,
                          scale: float):
    """One KV tile of the online-softmax recurrence, updating the VMEM
    scratch (running max, running sum, output accumulator) in place.

    q (bq, D), k/v (bk, D) fp32; mask (bq, bk) bool or None. The single
    definition of the flash tile math — shared by ``_flash_kernel`` and
    the packed-KV kernel/fallback in ``flash_attention_packed``, which is
    what makes fused-vs-oracle parity bit-exact rather than allclose.
    """
    online_softmax_update_scores(attention_scores(q, k, scale), v, mask,
                                 m_scr, l_scr, acc_scr)


def online_softmax_update_scores(s, v, mask, m_scr, l_scr, acc_scr):
    """The softmax/PV half of :func:`online_softmax_update`, taking the
    score tile ``s`` (bq, bk) fp32 pre-computed — the entry point for the
    packed kernel's integer-MAC score mode (same float sequence from the
    masking onward, whichever MAC produced ``s``)."""
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    m_prev = m_scr[...]                                   # (bq, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new
    l_scr[...] = l_new


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, k_steps: int, causal: bool,
                  window: int, q_offset: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # (bq, D)
    k = k_ref[0].astype(jnp.float32)                     # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    mask = tile_position_mask(bq, bk, qi, ki, causal, window, q_offset)
    online_softmax_update(q, k, v, mask, m_scr, l_scr, acc_scr, scale)

    @pl.when(ki == k_steps - 1)
    def _store():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, causal: bool = True, window: int = 0,
                           q_offset: int = 0, bq: int = 256, bk: int = 512,
                           interpret: bool = True):
    """q (BH, T, D); k/v (BH, S, D) -> (BH, T, D)."""
    bh, t, d = q.shape
    s_len = k.shape[1]
    bq = min(bq, t)
    bk = min(bk, s_len)
    assert t % bq == 0 and s_len % bk == 0, (t, bq, s_len, bk)
    k_steps = s_len // bk
    grid = (bh, t // bq, k_steps)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, k_steps=k_steps, causal=causal,
        window=window, q_offset=q_offset, scale=d ** -0.5)
    from jax.experimental.pallas import tpu as pltpu
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
