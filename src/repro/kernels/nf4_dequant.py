"""Pallas TPU kernel: NF4 dequantization (codebook lookup + per-block absmax
scale) — the QLoRA DQ(W^NF4) step on the frozen-base path of GSQ-Tuning.

Layout: codes (M, K) uint8 holding NF4 indices (one per value; the 2x packed
form is a storage concern — the kernel consumes the unpacked index plane).
absmax is the first-level scale per 64-value block along flattened (M, K);
we require K % 64 == 0 so blocks never straddle rows and the scale tile is
(BM, BK/64).

The 16-entry codebook lives in VMEM; the lookup is a one-hot (16-way)
select — gather-free, VPU-friendly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.nf4 import NF4_CODE, BLOCK

DEFAULT_BM = 256
DEFAULT_BK = 512


def _nf4_dequant_kernel(codes_ref, scale_ref, o_ref, *, out_dtype):
    codes = codes_ref[...].astype(jnp.int32)               # (BM, BK)
    scales = scale_ref[...].astype(jnp.float32)            # (BM, BK/64)
    bm, bk = codes.shape
    # gather-free LUT: sum_i (codes == i) * code[i]  (scalar immediates —
    # no captured constants, VPU-friendly selects)
    vals = jnp.zeros(codes.shape, jnp.float32)
    for i in range(16):
        vals = vals + jnp.where(codes == i, float(NF4_CODE[i]), 0.0)
    vals = vals.reshape(bm, bk // BLOCK, BLOCK)
    out = vals * scales[..., None]
    o_ref[...] = out.reshape(bm, bk).astype(out_dtype)


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "bm", "bk", "interpret"))
def nf4_dequant_pallas(codes: jax.Array, absmax: jax.Array,
                       out_dtype=jnp.bfloat16, bm: int = DEFAULT_BM,
                       bk: int = DEFAULT_BK, interpret: bool = True):
    """codes (M, K) uint8; absmax (M*K//64,) fp32 -> (M, K) out_dtype."""
    m_dim, k_dim = codes.shape
    assert k_dim % BLOCK == 0, k_dim
    bm = min(bm, m_dim)
    bk = min(bk, k_dim)
    assert m_dim % bm == 0 and k_dim % bk == 0 and bk % BLOCK == 0
    scales = absmax.reshape(m_dim, k_dim // BLOCK)
    grid = (m_dim // bm, k_dim // bk)
    kernel = functools.partial(_nf4_dequant_kernel, out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // BLOCK), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_dim, k_dim), out_dtype),
        interpret=interpret,
    )(codes, scales)
