"""Pallas TPU kernel: GSE quantization (find shared group exponent, shift
mantissas) — the paper's "Transform FP to GSE" (Sec. 2.2) as a tiled VMEM
kernel.

Layout: x (M, K) grouped along K (the contraction axis) with group size G.
Grid tiles (BM, BK) with BK a multiple of G; the exponent tile is (BM, BK/G).
Rounding is round-to-nearest-even, matching the jnp oracle bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.gse import (EXP_MIN, EXP_MAX, as_f32_exact, ceil_log2,
                            exp2_int, qmax_for_bits)

DEFAULT_BM = 256
DEFAULT_BK = 512


def quantize_tile(x: jax.Array, bits: int, group: int):
    """(BM, BK) float tile -> (mantissa fp-valued (BM, BK), exponent fp
    (BM, BK/G)): amax -> shared exponent (zero groups pinned to EXP_MIN)
    -> clipped round-to-nearest-even mantissas.

    The single definition of the on-chip quantize math — shared by this
    kernel and the fused quantize+pack kernel, which both carry the
    bit-exact parity contract vs ``repro.core.gse.gse_quantize``.
    """
    x = as_f32_exact(x)
    bm, bk = x.shape
    qmax = qmax_for_bits(bits)
    xg = x.reshape(bm, bk // group, group)
    amax = jnp.max(jnp.abs(xg), axis=-1)                  # (BM, BK/G)
    safe = jnp.where(amax > 0, amax, 1.0)
    # exact exponent math (repro.core.gse.ceil_log2/exp2_int): identical in
    # any fusion context — the cross-program bit-exact parity contract
    e = ceil_log2(safe / qmax)
    e = jnp.where(amax > 0, e, EXP_MIN)
    e = jnp.clip(e, EXP_MIN, EXP_MAX)
    scale = exp2_int(e)[..., None]                        # (BM, BK/G, 1)
    m = jnp.clip(jnp.round(xg / scale), -qmax, qmax)
    return m.reshape(bm, bk), e


def _gse_quant_kernel(x_ref, m_ref, e_ref, *, bits: int, group: int):
    m, e = quantize_tile(x_ref[...], bits, group)
    m_ref[...] = m.astype(jnp.int8)
    e_ref[...] = e.astype(jnp.int8)


@functools.partial(jax.jit,
                   static_argnames=("bits", "group", "bm", "bk",
                                    "interpret"))
def gse_quantize_pallas(x: jax.Array, bits: int = 6, group: int = 32,
                        bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                        interpret: bool = True):
    """x: (M, K) -> (mantissa int8 (M, K), exponent int8 (M, K//group)).

    M % bm == 0 and K % bk == 0 required (callers pad); bk % group == 0.
    """
    m_dim, k_dim = x.shape
    bm = min(bm, m_dim)
    bk = min(bk, k_dim)
    assert k_dim % bk == 0 and m_dim % bm == 0 and bk % group == 0, (
        x.shape, bm, bk, group)
    grid = (m_dim // bm, k_dim // bk)
    kernel = functools.partial(_gse_quant_kernel, bits=bits, group=group)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bk // group), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_dim, k_dim), jnp.int8),
            jax.ShapeDtypeStruct((m_dim, k_dim // group), jnp.int8),
        ],
        interpret=interpret,
    )(x)
