"""Memory-efficient (flash-style) attention in pure JAX: online softmax over
double-chunked (query x key) blocks. This is the reference implementation for
the Pallas TPU kernel in ``repro.kernels.flash_attention`` and the execution
path for every large (T x S) attention in the framework — full-score
materialization at 32k prefill would need ~PB of HBM.

Masking is *structural* (offset / causal / sliding-window / traced
``is_global``): blocks build their own (qc, kc) masks from positions, so no
(T, S) mask is ever materialized.

Softmax runs in fp32 (paper: non-linear ops stay high precision); the
block GEMMs run in the input dtype (bf16 on TPU) with fp32 accumulation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class MaskInfo:
    """Structural attention mask. All fields trace-safe.

    q_offset: absolute position of query row 0 (0 train, cache index
              decode) — a scalar shared by the batch or a per-sequence
              ``(B,)`` vector (ragged continuous-batching decode).
    causal:   static bool.
    window:   static int (0 = none) — sliding window size.
    is_global: traced bool or None — hymba per-layer override of window.
    """
    q_offset: object = 0
    causal: bool = True
    window: int = 0
    is_global: Optional[object] = None


def offset_qpos(q_offset, t: int, base=0):
    """Absolute query positions for a block of ``t`` rows starting at
    ``base``: (t,) for a scalar offset, (B, t) for a per-sequence
    vector — every mask consumer broadcasts over whichever it gets."""
    off = jnp.asarray(q_offset)
    pos = base + jnp.arange(t)
    return off[..., None] + pos if off.ndim else off + pos


def block_mask(qpos: jax.Array, kpos: jax.Array, info: MaskInfo):
    """(qc, kc) — or (B, qc, kc) for per-sequence ``qpos`` — bool mask for
    one block, or None if unmasked."""
    if not info.causal and not info.window:
        return None
    qp = qpos[..., :, None]
    kp = kpos[None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if info.causal:
        m = m & (kp <= qp)
    if info.window:
        local = kp > (qp - info.window)
        if info.is_global is not None:
            m = m & (local | info.is_global)
        else:
            m = m & local
    return m


def expand_mask(m):
    """Broadcast a block mask to score rank 5: (qc, kc) -> shared across
    (B, KV, G); (B, qc, kc) -> per-sequence, shared across (KV, G)."""
    return m[None, None, None] if m.ndim == 2 else m[:, None, None]


def _block_scores(q, k, qpos, kpos, info: MaskInfo, scale):
    """One (qc x kc) block of masked fp32 scores.

    q: (B, qc, KV, G, D); k: (B, kc, KV, D) -> (B, KV, G, qc, kc).
    """
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    m = block_mask(qpos, kpos, info)
    if m is not None:
        s = jnp.where(expand_mask(m), s, NEG_INF)
    return s


def flash_attention_ref(q, k, v, info: MaskInfo, *,
                        q_chunk: int = 512, k_chunk: int = 1024):
    """q: (B, T, H, D); k/v: (B, S, KV, D) -> (B, T, H, D).

    Online-softmax over k chunks (inner scan) per q chunk (outer scan).
    """
    b, t, h, d = q.shape
    s_len, kv = k.shape[1], k.shape[2]
    g = h // kv
    qc = min(q_chunk, t)
    kc = min(k_chunk, s_len)
    assert t % qc == 0 and s_len % kc == 0, (t, qc, s_len, kc)
    nq, nk = t // qc, s_len // kc
    scale = d ** -0.5

    # chunk axes lead so scans consume them as xs (no dynamic gathers)
    qr = q.reshape(b, nq, qc, kv, g, d).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(b, nk, kc, kv, d).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kc, kv, d).transpose(1, 0, 2, 3, 4)
    kidx = jnp.arange(nk)
    qidx = jnp.arange(nq)

    def q_step(_, q_in):
        qblk, qi = q_in                               # (B,qc,KV,G,D)
        qpos = offset_qpos(info.q_offset, qc, qi * qc)

        def k_step(carry, k_in):
            kblk, vblk, ki = k_in
            m_prev, l_prev, acc = carry
            kpos = ki * kc + jnp.arange(kc)
            sblk = _block_scores(qblk, kblk, qpos, kpos, info, scale)
            m_new = jnp.maximum(m_prev, jnp.max(sblk, axis=-1))
            p = jnp.exp(sblk - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kv, g, qc, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(k_step, (m0, l0, a0),
                                          (kr, vr, kidx))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        # (B,KV,G,qc,D) -> (B,qc,KV,G,D)
        return None, out.transpose(0, 3, 1, 2, 4)

    _, outs = jax.lax.scan(q_step, None, (qr, qidx))
    # outs: (nq, B, qc, KV, G, D)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, t, h, d)
    return out.astype(q.dtype)


def direct_attention(q, k, v, info: MaskInfo, scale=None):
    """Materialized-scores attention for small T x S (decode, tests)."""
    b, t, h, d = q.shape
    s_len, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale or d ** -0.5
    qg = q.reshape(b, t, kv, g, d)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                        preferred_element_type=jnp.float32) * scale
    qpos = offset_qpos(info.q_offset, t)
    kpos = jnp.arange(s_len)
    m = block_mask(qpos, kpos, info)
    if m is not None:
        scores = jnp.where(expand_mask(m), scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs.astype(v.dtype), v)
    return out.reshape(b, t, h, d)


def _flash_fwd_lse(q, k, v, info: MaskInfo, q_chunk: int, k_chunk: int):
    """Forward that also returns the per-row logsumexp (for the VJP).

    Returns out (B,T,H,D) and lse (B,KV,G,T) fp32.
    """
    b, t, h, d = q.shape
    s_len, kv = k.shape[1], k.shape[2]
    g = h // kv
    qc, kc = min(q_chunk, t), min(k_chunk, s_len)
    nq, nk = t // qc, s_len // kc
    scale = d ** -0.5
    qr = q.reshape(b, nq, qc, kv, g, d).transpose(1, 0, 2, 3, 4, 5)
    kr = k.reshape(b, nk, kc, kv, d).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kc, kv, d).transpose(1, 0, 2, 3, 4)
    kidx, qidx = jnp.arange(nk), jnp.arange(nq)

    def q_step(_, q_in):
        qblk, qi = q_in
        qpos = offset_qpos(info.q_offset, qc, qi * qc)

        def k_step(carry, k_in):
            kblk, vblk, ki = k_in
            m_prev, l_prev, acc = carry
            kpos = ki * kc + jnp.arange(kc)
            sblk = _block_scores(qblk, kblk, qpos, kpos, info, scale)
            m_new = jnp.maximum(m_prev, jnp.max(sblk, axis=-1))
            p = jnp.exp(sblk - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kv, g, qc, d), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(k_step, (m0, l0, a0),
                                          (kr, vr, kidx))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        lse = m_f + jnp.log(jnp.maximum(l_f, 1e-30))
        return None, (out.transpose(0, 3, 1, 2, 4), lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (qr, qidx))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, t, h, d).astype(
        q.dtype)
    # lses: (nq, B, KV, G, qc) -> (B, KV, G, T)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, kv, g, t)
    return out, lse


def _flash_bwd(info: MaskInfo, q_chunk: int, k_chunk: int, res, do):
    """FlashAttention-2-style backward: per-block score recomputation from
    (q, k, v, out, lse) — no stored probability blocks."""
    q, k, v, out, lse = res
    b, t, h, d = q.shape
    s_len, kv = k.shape[1], k.shape[2]
    g = h // kv
    qc, kc = min(q_chunk, t), min(k_chunk, s_len)
    nq, nk = t // qc, s_len // kc
    scale = d ** -0.5

    # delta = rowsum(dO * O) : (B, KV, G, T)
    delta = jnp.einsum("bthd,bthd->bth", do.astype(jnp.float32),
                       out.astype(jnp.float32))
    delta = delta.reshape(b, t, kv, g).transpose(0, 2, 3, 1)

    qr = q.reshape(b, nq, qc, kv, g, d).transpose(1, 0, 2, 3, 4, 5)
    dor = do.reshape(b, nq, qc, kv, g, d).transpose(1, 0, 2, 3, 4, 5)
    lser = lse.reshape(b, kv, g, nq, qc).transpose(3, 0, 1, 2, 4)
    deltar = delta.reshape(b, kv, g, nq, qc).transpose(3, 0, 1, 2, 4)
    kr = k.reshape(b, nk, kc, kv, d).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kc, kv, d).transpose(1, 0, 2, 3, 4)
    kidx, qidx = jnp.arange(nk), jnp.arange(nq)

    def k_outer(_, k_in):
        kblk, vblk, ki = k_in
        kpos = ki * kc + jnp.arange(kc)

        def q_inner(carry, q_in):
            dk_acc, dv_acc = carry
            qblk, doblk, lseblk, dblk, qi = q_in
            qpos = offset_qpos(info.q_offset, qc, qi * qc)
            sblk = _block_scores(qblk, kblk, qpos, kpos, info, scale)
            p = jnp.exp(sblk - lseblk[..., None])          # (B,KV,G,qc,kc)
            dv_acc = dv_acc + jnp.einsum(
                "bkgqs,bqkgd->bskd", p.astype(do.dtype), doblk,
                preferred_element_type=jnp.float32)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", doblk, vblk,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dblk[..., None]) * scale
            dk_acc = dk_acc + jnp.einsum(
                "bkgqs,bqkgd->bskd", ds.astype(q.dtype), qblk,
                preferred_element_type=jnp.float32)
            dq_blk = jnp.einsum("bkgqs,bskd->bqkgd", ds.astype(q.dtype),
                                kblk, preferred_element_type=jnp.float32)
            return (dk_acc, dv_acc), dq_blk

        dk0 = jnp.zeros((b, kc, kv, d), jnp.float32)
        dv0 = jnp.zeros((b, kc, kv, d), jnp.float32)
        (dk_f, dv_f), dq_blocks = jax.lax.scan(
            q_inner, (dk0, dv0), (qr, dor, lser, deltar, qidx))
        return None, (dk_f, dv_f, dq_blocks)

    _, (dks, dvs, dq_all) = jax.lax.scan(k_outer, None, (kr, vr, kidx))
    # dq_all: (nk, nq, B, qc, KV, G, D) -> sum over nk
    dq = jnp.sum(dq_all, axis=0).transpose(1, 0, 2, 3, 4, 5).reshape(
        b, t, h, d).astype(q.dtype)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, s_len, kv, d).astype(
        k.dtype)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, s_len, kv, d).astype(
        v.dtype)
    return dq, dk, dv


# custom_vjp static args must be hashable, but MaskInfo can carry tracers
# (decode q_offset, hymba per-layer is_global). The traced parts travel as
# f32 scalar arrays (zero cotangent in bwd); causal/window/chunks stay
# static.

def _mk_info(q_off_f, ig_f, causal, window):
    ig = (ig_f > 0.5) if window else None
    return MaskInfo(q_offset=q_off_f.astype(jnp.int32), causal=causal,
                    window=window, is_global=ig)


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash_core(q, k, v, q_off_f, ig_f, causal, window, q_chunk, k_chunk):
    out, _ = _flash_fwd_lse(q, k, v, _mk_info(q_off_f, ig_f, causal,
                                              window), q_chunk, k_chunk)
    return out


def _fa_fwd(q, k, v, q_off_f, ig_f, causal, window, q_chunk, k_chunk):
    out, lse = _flash_fwd_lse(q, k, v, _mk_info(q_off_f, ig_f, causal,
                                                window), q_chunk, k_chunk)
    return out, (q, k, v, out, lse, q_off_f, ig_f)


def _fa_bwd(causal, window, q_chunk, k_chunk, res, do):
    q, k, v, out, lse, q_off_f, ig_f = res
    dq, dk, dv = _flash_bwd(_mk_info(q_off_f, ig_f, causal, window),
                            q_chunk, k_chunk, (q, k, v, out, lse), do)
    return dq, dk, dv, jnp.zeros_like(q_off_f), jnp.zeros_like(ig_f)


_flash_core.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q, k, v, info: MaskInfo, q_chunk: int = 512,
                    k_chunk: int = 1024):
    q_off_f = jnp.asarray(info.q_offset, jnp.float32)
    ig = info.is_global
    ig_f = jnp.asarray(False if ig is None else ig, jnp.float32)
    return _flash_core(q, k, v, q_off_f, ig_f, info.causal, info.window,
                       q_chunk, k_chunk)


def attention(q, k, v, info: MaskInfo, *, q_chunk: int = 512,
              k_chunk: int = 1024, force_direct: bool = False):
    """Dispatch: direct for decode/small shapes, chunked otherwise."""
    t, s_len = q.shape[1], k.shape[1]
    if force_direct or t == 1 or (t * s_len <= 1024 * 1024
                                  and t % q_chunk != 0):
        return direct_attention(q, k, v, info)
    if t % q_chunk != 0 or s_len % k_chunk != 0:
        return direct_attention(q, k, v, info)
    return flash_attention(q, k, v, info, q_chunk, k_chunk)


def packed_attention(q, k_words, k_exp, v_words, v_exp, info: MaskInfo, *,
                     k_tail=None, v_tail=None, k_chunk: int = 512,
                     kv_active_bits: int | None = None, kv_trunc=None):
    """Attention against a **bit-packed** GSE KV cache (row-planar planes,
    see ``repro.kernels.flash_attention_packed``) — the packed decode call
    path. K/V stay packed end to end; only one KV tile is ever dequantized
    at a time (VMEM tile on TPU, scan-local tile on CPU). ``info`` fields
    may be traced (decode ``q_offset``, hymba ``is_global``) — both the
    kernel (scalar-prefetch offset, GQA grid) and the jnp fallback serve
    traced decode offsets; routing is ``repro.kernels.ops``'s job.

    ``k_tail``/``v_tail`` (B, Tt, Kv, D): the current decode step's fp
    k/v rows, attended at positions ``info.q_offset + arange(Tt)`` while
    packed positions ``>= q_offset`` are masked (quantize-after-attend
    append — the current token is never attended through its own
    quantization).

    ``kv_active_bits`` attends through a plane-prefix view of the stored
    planes (read b of the stored bits — docs/gse-format.md §7);
    ``kv_trunc`` adds per-sequence plane shifts below that width.

    q (B, T, H, D); planes (B, S, Kv, ·) -> (B, T, H, D).
    """
    from repro.kernels.ops import flash_attention_packed
    return flash_attention_packed(
        q, k_words, k_exp, v_words, v_exp, causal=info.causal,
        window=info.window, q_offset=info.q_offset,
        is_global=info.is_global, k_tail=k_tail, v_tail=v_tail,
        bk=k_chunk, kv_active_bits=kv_active_bits, kv_trunc=kv_trunc)


def paged_attention(q, kp_words, kp_exp, vp_words, vp_exp, page_table,
                    info: MaskInfo, *, k_tail=None, v_tail=None,
                    k_chunk: int = 512,
                    kv_active_bits: int | None = None, kv_trunc=None):
    """Attention against a **paged** packed-KV pool: the row-planar plane
    layout carved into fixed-size pages (``repro.serve.paging``), with each
    sequence's logical KV order given by its ``page_table`` row. The
    continuous-batching decode call path — ``info.q_offset`` is the
    per-sequence ``(B,)`` length vector; routing (page-walking kernel vs
    gather + packed fallback) is ``repro.kernels.ops``'s job.

    ``kv_active_bits`` reads a static plane prefix of every page;
    ``kv_trunc`` (B,) rides the scalar-prefetch lane so each lane decodes
    at its own effective width from the one pool (mixed-``kv_bits``
    serving).

    q (B, T, H, D); pools (P, page, Kv, ·); page_table (B, maxp) int32
    -> (B, T, H, D).
    """
    from repro.kernels.ops import flash_attention_paged
    return flash_attention_paged(
        q, kp_words, kp_exp, vp_words, vp_exp, page_table,
        causal=info.causal, window=info.window, q_offset=info.q_offset,
        is_global=info.is_global, k_tail=k_tail, v_tail=v_tail,
        k_chunk=k_chunk, kv_active_bits=kv_active_bits, kv_trunc=kv_trunc)
