"""Unified language model over all assigned families (dense / moe / ssm /
hybrid / encdec / vlm / audio) with GSQ-Tuning quantization throughout.

The layer stack is a ``jax.lax.scan`` over vmap-stacked per-layer params
(keeps HLO size O(1) in depth — essential for 512-device dry-run compiles)
with optional rematerialization.

Public entry points:
  init_model(key, cfg, policy)            -> (frozen, train)
  forward(frozen, train, batch, cfg, pol) -> logits     (teacher forcing)
  decode_step(...)                        -> logits, cache (one token)
  init_decode_cache(...)
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.policy import QuantPolicy
from repro.distributed.sharding import shard
from repro.models.config import ModelConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.attention import MaskInfo

_MASK_BIDIR = MaskInfo(causal=False)

# per-layer cache keys owned by the attention mixer: unpacked k/v or the
# row-planar packed planes (packed decode path), the paged page-pool
# planes + page table (continuous-batching serving path), plus the write
# index
_ATTN_CACHE_KEYS = ("k", "v", "index", "k_words", "k_exp", "v_words",
                    "v_exp", "kp_words", "kp_exp", "vp_words", "vp_exp",
                    "pages", "kv_trunc")


def _attn_cache_view(layer_cache):
    return {k: layer_cache[k] for k in _ATTN_CACHE_KEYS if k in layer_cache}


def _remat_policy(policy: QuantPolicy):
    """Checkpoint policy for the layer-stack remat.

    With packed QCD residuals the blocks carry their backward GEMM operands
    as ``qcd_xq``/``qcd_wq``-named packed word streams (b + 5/group
    bits/value — repro.core.qcd); saving exactly those across the replay
    skips the re-quantize+pack of every GEMM input at a storage cost far
    below one bf16 activation. (§Perf iter 6 measured save_only_these_names
    WORSE when the named residual was the full bf16 ``qcd_wq`` — packing is
    what flips the trade.) Legacy fake-quant residuals are full-width, so
    there the old full-remat posture stays — and partially-quantized
    ablations (any GEMM bit-width None) fall back to that legacy path,
    whose full-width ``qcd_wq`` name must NOT be pinned across the replay:
    the names policy applies only when every QCD GEMM in the model
    (base a/w bits, and adapter bits when adapters exist) runs packed."""
    every_gemm_packed = (
        policy.residuals_packed and policy.fmt == "gse"
        and policy.a_bits is not None and policy.w_bits is not None
        and (policy.rank == 0 or policy.adapter_bits is not None))
    if every_gemm_packed:
        return jax.checkpoint_policies.save_only_these_names(
            "qcd_xq", "qcd_wq")
    return jax.checkpoint_policies.nothing_saveable


# --------------------------------------------------------------------------
# Per-layer init / apply by family
# --------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, policy: QuantPolicy,
                cross: bool = False):
    """One block. ``cross=True`` adds cross-attention (whisper decoder)."""
    keys = jax.random.split(key, 8)
    fz, tr = {}, {}
    fam = cfg.family
    fz["ln1"] = L.norm_init(cfg)
    if fam == "ssm":
        fz["ssm"], tr["ssm"] = S.ssm_init(keys[0], cfg, policy)
        return fz, tr
    fz["attn"], tr["attn"] = L.attn_init(keys[0], cfg, policy)
    if cfg.hybrid:
        fz["ssm"], tr["ssm"] = S.ssm_init(keys[1], cfg, policy)
        fz["attn_out_norm"] = L.rmsnorm_init(cfg.d_model)
        fz["ssm_out_norm"] = L.rmsnorm_init(cfg.d_model)
    if cross:
        fz["ln_cross"] = L.norm_init(cfg)
        fz["cross"], tr["cross"] = L.attn_init(keys[2], cfg, policy,
                                               cross=True)
    fz["ln2"] = L.norm_init(cfg)
    if cfg.n_experts:
        fz["moe"], tr["moe"] = L.moe_init(keys[3], cfg, policy)
        if cfg.dense_residual:
            fz["mlp"], tr["mlp"] = L.mlp_init(keys[4], cfg, policy)
    else:
        fz["mlp"], tr["mlp"] = L.mlp_init(keys[4], cfg, policy)
    return fz, tr


def _mixer(fz, tr, h, cfg, policy, *, positions, mask_info, layer_cache,
           ring_buffer, use_rope, is_global=None, enc_kv=None):
    """Token mixer of a block: attention / ssm / both (hybrid)."""
    new_cache = {}
    if cfg.family == "ssm":
        y, sc = S.ssm_apply(fz["ssm"], tr["ssm"], h, cfg, policy,
                            cache=layer_cache)
        return y, (sc if sc is not None else {})
    if cfg.hybrid:
        attn_cache = _attn_cache_view(layer_cache) if layer_cache else None
        ssm_cache = {k: layer_cache[k] for k in ("state", "conv")} \
            if layer_cache else None
        ya, ac = L.attn_apply(fz["attn"], tr["attn"], h, cfg, policy,
                              positions=positions, mask_info=mask_info,
                              layer_cache=attn_cache,
                              ring_buffer=ring_buffer, use_rope=use_rope)
        ys, sc = S.ssm_apply(fz["ssm"], tr["ssm"], h, cfg, policy,
                             cache=ssm_cache)
        # Hymba: normalize each head-type output, then average
        y = 0.5 * (L.rmsnorm(fz["attn_out_norm"], ya, cfg.norm_eps)
                   + L.rmsnorm(fz["ssm_out_norm"], ys, cfg.norm_eps))
        if ac is not None:
            new_cache.update(_attn_cache_view(ac))
        if sc is not None:
            new_cache.update(sc)
        return y, new_cache
    y, ac = L.attn_apply(fz["attn"], tr["attn"], h, cfg, policy,
                         positions=positions, mask_info=mask_info,
                         layer_cache=layer_cache, ring_buffer=ring_buffer,
                         use_rope=use_rope)
    return y, (ac if ac is not None else {})


def _block_apply(fz, tr, x, cfg: ModelConfig, policy: QuantPolicy, *,
                 positions, mask_info=None, layer_cache=None,
                 ring_buffer=False, use_rope=True, is_global=None,
                 enc_kv=None):
    """Pre-norm residual block; returns (x_out, new_layer_cache)."""
    h = L.norm_apply(cfg, fz["ln1"], x)
    t = x.shape[1]
    if layer_cache is not None and ("k" in layer_cache
                                    or "k_words" in layer_cache
                                    or "kp_words" in layer_cache):
        # Decode/prefill: positions and mask derive from the cache index —
        # a shared scalar (static batches) or a per-sequence (B,) vector
        # (ragged serving batches: each row's RoPE/mask use its own offset).
        idx = jnp.asarray(layer_cache["index"], jnp.int32)
        qpos = idx[..., None] + jnp.arange(t)    # (1, T) or (B, T)
        mask_info = MaskInfo(q_offset=idx, causal=True,
                             window=cfg.sliding_window or 0,
                             is_global=is_global if cfg.sliding_window
                             else None)
        positions = jnp.broadcast_to(qpos if qpos.ndim == 2 else qpos[None],
                                     (x.shape[0], t))
    elif mask_info is None:
        mask_info = MaskInfo(q_offset=0, causal=cfg.causal,
                             window=cfg.sliding_window or 0,
                             is_global=is_global if cfg.sliding_window
                             else None)
    elif cfg.sliding_window and is_global is not None:
        mask_info = MaskInfo(q_offset=mask_info.q_offset,
                             causal=mask_info.causal,
                             window=cfg.sliding_window,
                             is_global=is_global)
    y, new_cache = _mixer(fz, tr, h, cfg, policy, positions=positions,
                          mask_info=mask_info, layer_cache=layer_cache,
                          ring_buffer=ring_buffer, use_rope=use_rope,
                          is_global=is_global, enc_kv=enc_kv)
    x = x + y
    if cfg.family == "ssm":
        return x, new_cache
    if enc_kv is not None:                       # whisper decoder cross-attn
        h = L.norm_apply(cfg, fz["ln_cross"], x)
        x = x + L.cross_attn_apply(fz["cross"], tr["cross"], h, enc_kv,
                                   cfg, policy)
    h = L.norm_apply(cfg, fz["ln2"], x)
    if cfg.n_experts:
        y = L.moe_apply(fz["moe"], tr["moe"], h, cfg, policy)
        if cfg.dense_residual:
            y = y + L.mlp_apply(fz["mlp"], tr["mlp"], h, cfg, policy)
    else:
        y = L.mlp_apply(fz["mlp"], tr["mlp"], h, cfg, policy)
    x = x + y
    x = shard(x, "batch", None, "embed")
    return x, new_cache


# --------------------------------------------------------------------------
# Model init
# --------------------------------------------------------------------------

def init_model(key, cfg: ModelConfig, policy: QuantPolicy):
    """Returns (frozen, train). Layer params are stacked along a leading L
    axis via vmap so the stack can be scanned."""
    k_emb, k_layers, k_enc, k_unemb = jax.random.split(key, 4)
    vp, d = cfg.padded_vocab, cfg.d_model
    fz, tr = {}, {}
    fz["embed"] = (jax.random.normal(k_emb, (vp, d), jnp.float32)
                   * (d ** -0.5)).astype(jnp.bfloat16)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    cross = cfg.is_encoder_decoder
    init_fn = partial(_layer_init, cfg=cfg, policy=policy, cross=cross)
    fz["layers"], tr["layers"] = jax.vmap(init_fn)(layer_keys)
    fz["final_norm"] = L.norm_init(cfg)
    if not cfg.tie_embeddings:
        fz["unembed"] = (jax.random.normal(k_unemb, (d, vp), jnp.float32)
                         * (d ** -0.5)).astype(jnp.bfloat16)
    if cfg.is_encoder_decoder:
        enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
        enc_init = partial(_layer_init, cfg=cfg, policy=policy, cross=False)
        fz["enc_layers"], tr["enc_layers"] = jax.vmap(enc_init)(enc_keys)
        fz["enc_final_norm"] = L.norm_init(cfg)
    return fz, tr


# --------------------------------------------------------------------------
# Layer-stack scan
# --------------------------------------------------------------------------

def _scan_stack(fz_stack, tr_stack, x, cfg, policy, *, positions,
                mask_info=None, use_rope=True, enc_kv=None,
                is_global_flags=None, cache=None, ring_flags=None):
    """Scan a stacked layer tree. cache (if given) is a stacked per-layer
    dict; returns (x, new_cache)."""
    remat = cfg.remat and cache is None

    def body(carry, per_layer):
        h = carry
        fz_l, tr_l, ig, cache_l = per_layer

        def run(h, fz_l, tr_l, cache_l):
            return _block_apply(
                fz_l, tr_l, h, cfg, policy, positions=positions,
                mask_info=mask_info, layer_cache=cache_l, ring_buffer=False,
                use_rope=use_rope, is_global=ig, enc_kv=enc_kv)

        if remat:
            run = jax.checkpoint(run, policy=_remat_policy(policy))
        h, new_cache_l = run(h, fz_l, tr_l, cache_l)
        return h, new_cache_l

    n = cfg.n_layers if is_global_flags is None else len(is_global_flags)
    ig = (jnp.zeros((n,), bool) if is_global_flags is None
          else jnp.asarray(is_global_flags))
    xs = (fz_stack, tr_stack, ig, cache)
    x, new_cache = jax.lax.scan(body, x, xs)
    return x, (new_cache if cache is not None else None)


# --------------------------------------------------------------------------
# Forward (teacher forcing) — training/prefill path
# --------------------------------------------------------------------------

def embed_inputs(fz, batch, cfg: ModelConfig, pos_offset=0):
    """tokens -> embeddings, or pass through precomputed frontend
    embeddings (vlm/audio stubs). ``pos_offset`` (traced ok) shifts the
    absolute-position embedding during decode."""
    if "inputs_embeds" in batch:
        x = batch["inputs_embeds"].astype(jnp.bfloat16)
    else:
        tok = batch["tokens"]
        x = fz["embed"][tok]
    if cfg.family == "encdec":                   # whisper: sinusoidal pos
        t = x.shape[1]
        off = jnp.asarray(pos_offset)
        # scalar offset -> shared (T,) positions; per-sequence (B,) vector
        # -> per-row (B, T) positions (ragged decode batches)
        pos = off[..., None] + jnp.arange(t) if off.ndim \
            else jnp.arange(t) + off
        d = cfg.d_model
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
        ang = pos.astype(jnp.float32)[..., :, None] / jnp.power(10000.0,
                                                                dim / d)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + (pe if pe.ndim == 3 else pe[None]).astype(x.dtype)
    return shard(x, "batch", None, "embed")


def norm_apply_final(fz, x, cfg: ModelConfig):
    return L.norm_apply(cfg, fz["final_norm"], x)


def forward_hidden(fz, tr, batch, cfg: ModelConfig, policy: QuantPolicy):
    """forward() up to (and including) the final norm — (B, T, d). The
    training loss fuses unembedding+CE per T-chunk on top of this so the
    (B, T, V) logits of big-vocab archs are never materialized."""
    x = embed_inputs(fz, batch, cfg)
    b, t, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    flags = None
    if cfg.global_attn_layers:
        flags = [i in cfg.global_attn_layers for i in range(cfg.n_layers)]
    if cfg.is_encoder_decoder:
        enc_out = encode(fz, tr, batch, cfg, policy)
        x, _ = _scan_stack_encdec(fz, tr, x, enc_out, cfg, policy,
                                  positions=positions)
    else:
        x, _ = _scan_stack(fz["layers"], tr["layers"], x, cfg, policy,
                           positions=positions,
                           use_rope=cfg.family != "encdec",
                           is_global_flags=flags)
    return norm_apply_final(fz, x, cfg)


def fused_ce_loss(fz, x, labels, loss_mask, cfg: ModelConfig,
                  t_chunk: int = 512):
    """sum-CE and token count, scanning T chunks of the unembed GEMM so only
    (B, tc, V) logits are live at once (vocab stays model-sharded).
    Backward recomputes each chunk's logits (checkpointed scan)."""
    w = (fz["embed"].T if cfg.tie_embeddings else fz["unembed"])
    b, t, d = x.shape
    tc = min(t_chunk, t)
    while t % tc != 0:
        tc -= 1
    nt = t // tc
    xs = (x.reshape(b, nt, tc, d).transpose(1, 0, 2, 3),
          labels.reshape(b, nt, tc).transpose(1, 0, 2),
          loss_mask.reshape(b, nt, tc).transpose(1, 0, 2))

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_loss(xc, lc, mc):
        logits = jnp.einsum("btd,dv->btv", xc, w.astype(xc.dtype),
                            preferred_element_type=jnp.float32)
        logits = shard(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - ll) * mc), jnp.sum(mc)

    def body(carry, inp):
        ls, ns = carry
        l, n = chunk_loss(*inp)
        return (ls + l, ns + n), None

    (loss_sum, n_tok), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), xs)
    return loss_sum, n_tok


def unembed(fz, x, cfg: ModelConfig):
    w = (fz["embed"].T if cfg.tie_embeddings else fz["unembed"])
    logits = jnp.einsum("btd,dv->btv", x, w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return shard(logits, "batch", None, "vocab")


def encode(fz, tr, batch, cfg: ModelConfig, policy: QuantPolicy):
    """Whisper encoder over stub frame embeddings (B, S_enc, d)."""
    x = batch["frames"].astype(jnp.bfloat16)
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model
                                   ).astype(x.dtype)[None]
    b, s, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x, _ = _scan_stack(fz["enc_layers"], tr["enc_layers"], x, cfg, policy,
                       positions=pos, mask_info=_MASK_BIDIR,
                       use_rope=False)
    return L.norm_apply(cfg, fz["enc_final_norm"], x)


def forward(fz, tr, batch, cfg: ModelConfig, policy: QuantPolicy):
    """Teacher-forcing forward -> logits (B, T, Vp)."""
    x = embed_inputs(fz, batch, cfg)
    b, t, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t)[None], (b, t))
    mask_info = None   # _block_apply builds the structural mask per layer
    enc_kv = None
    if cfg.is_encoder_decoder:
        enc_out = encode(fz, tr, batch, cfg, policy)
        # cross-attn k/v are computed per layer inside the block via the
        # layer's own cross projections: pass the raw encoder output and
        # project per layer (scan-invariant closure).
        enc_kv = enc_out
    flags = None
    if cfg.global_attn_layers:
        flags = [i in cfg.global_attn_layers for i in range(cfg.n_layers)]

    if enc_kv is not None:
        # For enc-dec we cannot close over per-layer cross projections in a
        # plain scan xs-free way; _block_apply projects enc_out per layer.
        x, _ = _scan_stack_encdec(fz, tr, x, enc_kv, cfg, policy,
                                  positions=positions)
    else:
        x, _ = _scan_stack(fz["layers"], tr["layers"], x, cfg, policy,
                           positions=positions,
                           use_rope=cfg.family != "encdec",
                           is_global_flags=flags)
    x = L.norm_apply(cfg, fz["final_norm"], x)
    return unembed(fz, x, cfg)


def _scan_stack_encdec(fz, tr, x, enc_out, cfg, policy, *, positions,
                       cache=None):
    """Decoder stack for whisper: per-layer cross-attention against
    ``enc_out`` (scan-invariant). During decode (cache given, enc_out=None)
    the per-layer cross k/v come from the cache ("ck"/"cv"), projected once
    at prefill."""
    remat = cfg.remat and cache is None

    def body(h, per_layer):
        fz_l, tr_l, cache_l = per_layer

        def run(h, fz_l, tr_l, cache_l):
            cross_keys = ()
            if enc_out is not None:
                ekv = L.cross_kv(fz_l["cross"], tr_l["cross"], enc_out, cfg,
                                 policy)
            elif "ck_words" in cache_l:      # packed cross cache (planes)
                cross_keys = ("ck_words", "ck_exp", "cv_words", "cv_exp")
                ekv = tuple(cache_l[k] for k in cross_keys)
            else:
                cross_keys = ("ck", "cv")
                ekv = (cache_l["ck"], cache_l["cv"])
            self_cache = None
            if cache_l is not None:
                self_cache = _attn_cache_view(cache_l)
            h, nc = _block_apply(fz_l, tr_l, h, cfg, policy,
                                 positions=positions,
                                 layer_cache=self_cache, use_rope=False,
                                 enc_kv=ekv)
            if cache_l is not None:
                nc = dict(nc, **{k: cache_l[k] for k in cross_keys})
            return h, nc
        if remat:
            run = jax.checkpoint(run, policy=_remat_policy(policy))
        h, nc = run(h, fz_l, tr_l, cache_l)
        return h, nc

    x, new_cache = jax.lax.scan(body, x, (fz["layers"], tr["layers"], cache))
    return x, (new_cache if cache is not None else None)
