"""Mamba-2 (SSD — state-space duality) layer, chunked training scan and O(1)
recurrent decode. arXiv:2405.21060.

GSQ integration (DESIGN §6): the in/out projections are GSQ linear layers
(NF4 base + GSE-QCD adapters). The SSD intra-chunk matmuls are
activation-activation GEMMs — their operands are GSE-quantized with a
straight-through estimator when the policy is quantized; the recurrence
itself (elementwise decays) stays 16/32-bit per the paper's non-linear-op
exemption.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gse import gse_fake_quant_ste
from repro.core.lora import init_gsq_linear, apply_gsq_linear
from repro.core.policy import QuantPolicy
from repro.models.config import ModelConfig
from repro.distributed.sharding import shard


def ssm_init(key, cfg: ModelConfig, policy: QuantPolicy):
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_heads
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_dim = di + 2 * g * n
    k_in, k_out, k_conv, k_dt = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * g * n + h          # z, x, B, C, dt
    fz, tr = {}, {}
    fz["in_proj"], tr["in_proj"] = init_gsq_linear(k_in, d, proj_out, policy)
    fz["out_proj"], tr["out_proj"] = init_gsq_linear(k_out, di, d, policy)
    fz["conv_w"] = (jax.random.normal(k_conv, (cfg.ssm_conv, conv_dim),
                                      jnp.float32) * (cfg.ssm_conv ** -0.5))
    fz["conv_b"] = jnp.zeros((conv_dim,), jnp.float32)
    # A in (-inf, 0): A = -exp(A_log); init A in [-1, ... ] standard
    fz["A_log"] = jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32))
    fz["D"] = jnp.ones((h,), jnp.float32)
    fz["dt_bias"] = jnp.log(jnp.expm1(
        jnp.exp(jax.random.uniform(k_dt, (h,), jnp.float32,
                                   jnp.log(1e-3), jnp.log(1e-1)))))
    fz["gate_norm"] = {"scale": jnp.ones((di,), jnp.float32)}
    return fz, tr


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, T, C); w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i: i + x.shape[1], :].astype(jnp.float32) * w[i]
    return (out + b).astype(x.dtype)


def _split_proj(p, cfg: ModelConfig):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z, xbc_dt = jnp.split(p, [di], axis=-1)
    xbc, dt = jnp.split(xbc_dt, [di + 2 * g * n], axis=-1)
    return z, xbc, dt


def _gated_out(fz, tr, y, z, cfg, policy, eps):
    """y * silu(z) -> RMSNorm -> out_proj."""
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + eps)
         * fz["gate_norm"]["scale"]).astype(y.dtype)
    return apply_gsq_linear(fz["out_proj"], tr["out_proj"], y, policy)


def _maybe_q(x, policy: QuantPolicy):
    if policy.fmt == "gse" and policy.a_bits is not None:
        from repro.core.qcd import effective_group_size
        gs = effective_group_size(x.shape[-1], policy.group_size)
        return gse_fake_quant_ste(x, policy.a_bits, gs)
    return x


def ssd_chunked(xh, dt, A, B_mat, C_mat, D, cfg: ModelConfig,
                policy: QuantPolicy,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD. Shapes:
      xh: (B, T, H, P)   dt: (B, T, H)   A: (H,) negative
      B_mat/C_mat: (B, T, G, N)  (H/G heads share each group)
    Returns y: (B, T, H, P) and final state (B, H, P, N).
    """
    b, t, h, p = xh.shape
    g, n = B_mat.shape[2], B_mat.shape[3]
    q = min(cfg.ssm_chunk, t)
    assert t % q == 0, (t, q)
    nc = t // q
    rep = h // g

    dtf = dt.astype(jnp.float32)
    la = dtf * A                                           # log decay (B,T,H)
    xw = xh * dtf[..., None].astype(xh.dtype)              # dt-weighted input

    la_c = la.reshape(b, nc, q, h)
    cum = jnp.cumsum(la_c, axis=2)                         # (B,Nc,Q,H)
    total = cum[:, :, -1, :]                               # (B,Nc,H)
    xw_c = xw.reshape(b, nc, q, h, p)
    B_c = B_mat.reshape(b, nc, q, g, n)
    C_c = C_mat.reshape(b, nc, q, g, n)
    # broadcast groups to heads
    B_h = jnp.repeat(B_c, rep, axis=3)                     # (B,Nc,Q,H,N)
    C_h = jnp.repeat(C_c, rep, axis=3)

    Bq = _maybe_q(B_h, policy)
    Cq = _maybe_q(C_h, policy)
    xq = _maybe_q(xw_c, policy)

    # --- intra-chunk (quadratic within chunk) ---
    scores = jnp.einsum("bcihn,bcjhn->bchij", Cq, Bq,
                        preferred_element_type=jnp.float32)
    cum_t = cum.transpose(0, 1, 3, 2)                      # (B,Nc,H,Q)
    # decay[b,c,h,i,j] = cum_i - cum_j ; mask j<=i
    decay = cum_t[:, :, :, :, None] - cum_t[:, :, :, None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    gmat = jnp.where(mask, jnp.exp(decay), 0.0)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp",
                         (scores * gmat).astype(xh.dtype), xq)

    # --- chunk boundary states ---
    # S_c = sum_j exp(total - cum_j) * B_j ⊗ x_j  : (B,Nc,H,N,P)
    w_state = jnp.exp(total[:, :, None, :] - cum)          # (B,Nc,Q,H)
    S_loc = jnp.einsum("bcjhn,bcjhp->bchnp",
                       (Bq.astype(jnp.float32)
                        * w_state[..., None]).astype(xh.dtype), xq)

    # --- inter-chunk recurrence over Nc ---
    chunk_decay = jnp.exp(total)                           # (B,Nc,H)

    def step(s, inp):
        s_loc, dec = inp                                   # (B,H,N,P), (B,H)
        s_new = s * dec[..., None, None] + s_loc.astype(jnp.float32)
        return s_new, s

    s0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final_state, s_prevs = jax.lax.scan(
        step, s0,
        (S_loc.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    s_prev = s_prevs.transpose(1, 0, 2, 3, 4)              # (B,Nc,H,N,P)

    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         (Cq.astype(jnp.float32)
                          * jnp.exp(cum)[..., None]).astype(xh.dtype),
                         s_prev.astype(xh.dtype))
    y = (y_intra + y_inter).reshape(b, t, h, p)
    y = y + xh * D[None, None, :, None].astype(xh.dtype)
    return y, final_state


def ssm_apply(fz, tr, x, cfg: ModelConfig, policy: QuantPolicy,
              cache: Optional[dict] = None
              ) -> Tuple[jax.Array, Optional[dict]]:
    """Full Mamba-2 mixer. Training path (cache=None) uses chunked SSD;
    decode path (cache: {"state": (B,H,N,P)?? , "conv": (B,K-1,C)}) does the
    O(1) recurrent update. T must be 1 in decode."""
    b, t, d = x.shape
    h, p = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    di = cfg.d_inner
    proj = apply_gsq_linear(fz["in_proj"], tr["in_proj"], x, policy)
    z, xbc, dt = _split_proj(proj, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + fz["dt_bias"][None, None, :])
    A = -jnp.exp(fz["A_log"].astype(jnp.float32))

    if cache is None or t > 1:
        # Training or prefill: chunked SSD over the whole sequence. When a
        # cache is given (prefill), seed from / write back the SSM state and
        # the conv ring tail.
        xbc_raw = xbc
        xbc = _causal_conv(xbc, fz["conv_w"], fz["conv_b"])
        xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
        xh, Bm, Cm = jnp.split(xbc, [di, di + g * n], axis=-1)
        xh = xh.reshape(b, t, h, p)
        xh = shard(xh, "batch", None, "ssm_heads", None)
        Bm = Bm.reshape(b, t, g, n)
        Cm = Cm.reshape(b, t, g, n)
        # pad T to a chunk multiple; padded steps get dt=0 (identity state
        # transition, zero input) so they are exact no-ops.
        q = min(cfg.ssm_chunk, t)
        pad = (-t) % q
        if pad:
            padt = lambda v: jnp.pad(v, ((0, 0), (0, pad)) +
                                     ((0, 0),) * (v.ndim - 2))
            xh, Bm, Cm, dt = padt(xh), padt(Bm), padt(Cm), padt(dt)
        init_state = None if cache is None else cache["state"]
        y, final_state = ssd_chunked(xh, dt, A, Bm, Cm, fz["D"], cfg, policy,
                                     init_state=init_state)
        y = y[:, :t].reshape(b, t, di)
        out = _gated_out(fz, tr, y, z, cfg, policy, cfg.norm_eps)
        if cache is None:
            return out, None
        kc = cfg.ssm_conv - 1
        new_cache = {"state": final_state.astype(cache["state"].dtype),
                     "conv": xbc_raw[:, t - kc:, :].astype(cache["conv"].dtype)}
        return out, new_cache

    # ---- decode: recurrent update (T == 1) ----
    conv_buf = cache["conv"]                               # (B, K-1, C)
    xbc_t = xbc[:, 0]                                      # (B, C)
    win = jnp.concatenate([conv_buf, xbc_t[:, None]], axis=1)  # (B,K,C)
    w = fz["conv_w"]                                       # (K, C)
    xbc_c = jnp.sum(win.astype(jnp.float32) * w[None], axis=1) + fz["conv_b"]
    xbc_c = jax.nn.silu(xbc_c).astype(x.dtype)             # (B, C)
    xh, Bm, Cm = jnp.split(xbc_c, [di, di + g * n], axis=-1)
    xh = xh.reshape(b, h, p)
    Bm = jnp.repeat(Bm.reshape(b, g, n), h // g, axis=1)   # (B,H,N)
    Cm = jnp.repeat(Cm.reshape(b, g, n), h // g, axis=1)
    dt1 = dt[:, 0]                                         # (B,H)
    a = jnp.exp(dt1 * A[None])                             # (B,H)
    state = cache["state"].astype(jnp.float32)             # (B,H,N,P)
    upd = (Bm * dt1[..., None])[..., :, None] * xh[:, :, None, :]
    state = state * a[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Cm.astype(jnp.float32), state)
    y = (y + xh.astype(jnp.float32) * fz["D"][None, :, None]).astype(x.dtype)
    y = y.reshape(b, 1, di)
    out = _gated_out(fz, tr, y, z, cfg, policy, cfg.norm_eps)
    new_cache = {"state": state.astype(cache["state"].dtype),
                 "conv": win[:, 1:]}
    return out, new_cache


def ssm_cache_init(cfg: ModelConfig, batch: int, n_layers: int,
                   dtype=jnp.float32):
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "state": jnp.zeros((n_layers, batch, h, n, p), dtype),
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_dim),
                          dtype),
    }
