"""Unified model configuration covering every assigned architecture family:
dense GQA transformers, MoE, SSM (Mamba-2 SSD), hybrid (Hymba), and
encoder-decoder (Whisper). One dataclass; family-specific fields default off.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


def pad_to_multiple(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"            # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: Optional[int] = None   # default d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1000
    act: str = "silu"                # silu(SwiGLU) | gelu(GeGLU) | gelu_mlp
    # attention details
    qkv_bias: bool = False           # qwen2 has QKV bias
    qk_norm: bool = False            # qwen3
    rope_theta: float = 10000.0
    causal: bool = True
    sliding_window: Optional[int] = None   # hymba SWA layers
    global_attn_layers: tuple = ()         # layer idxs with full attn (hymba)
    # norm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: Optional[int] = None        # per-expert hidden (d_ff if None)
    dense_residual: bool = False          # arctic: dense FFN in parallel w/ MoE
    capacity_factor: float = 1.25
    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    ssm_conv: int = 4
    # hybrid (hymba): parallel attn + ssm heads in each block
    hybrid: bool = False
    # encoder-decoder
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500               # whisper fixed 30s encoder grid
    # modality frontend stub ("none" | "audio" | "vlm")
    frontend: str = "none"
    # attention execution (flash-style chunking)
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024
    # packed-KV read width: attend through a plane-prefix view reading only
    # the first b of the cache's stored mantissa planes (None = stored
    # width; docs/gse-format.md §7). Static — per-sequence widths instead
    # ride the traced ``kv_trunc`` cache entry (serve.scheduler).
    kv_active_bits: Optional[int] = None
    # training-time knobs
    remat: bool = True
    vocab_pad_multiple: int = 2048

    # ---- derived --------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab, self.vocab_pad_multiple)

    @property
    def d_inner(self) -> int:           # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def uses_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate dense parameter count (for 6·N·D roofline math)."""
        d, hd = self.d_model, self.resolved_head_dim
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * hd \
            + self.n_heads * hd * d
        if self.act in ("silu", "gelu"):   # gated: 3 mats
            ff_dense = 3 * d * self.d_ff
        else:
            ff_dense = 2 * d * self.d_ff
        per_layer = 0
        if self.uses_attention and not self.hybrid:
            per_layer += attn
        if self.hybrid:
            per_layer += attn
        if self.uses_ssm:
            di, ng, ns = self.d_inner, self.ssm_groups, self.ssm_state
            per_layer += d * (2 * di + 2 * ng * ns + self.ssm_heads) + di * d
        if self.n_experts:
            eff = self.moe_d_ff or self.d_ff
            per_layer += self.n_experts * 3 * d * eff + d * self.n_experts
            if self.dense_residual:
                per_layer += ff_dense
        else:
            if self.family != "ssm":
                per_layer += ff_dense
        total = self.n_layers * per_layer
        if self.is_encoder_decoder:
            # encoder layers: self-attn + ff; decoder already counted has
            # cross-attn extra
            total += self.n_encoder_layers * (attn + ff_dense)
            total += self.n_layers * attn          # cross attention
        total += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> int:
        """MoE: active params per token (for 6·N_active·D)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        eff = self.moe_d_ff or self.d_ff
        full = self.param_count()
        moe_all = self.n_layers * self.n_experts * 3 * d * eff
        moe_active = self.n_layers * self.top_k * 3 * d * eff
        return full - moe_all + moe_active
