"""Composable transformer building blocks with GSQ-Tuning quantization.

Every projection GEMM goes through :func:`repro.core.lora.apply_gsq_linear`
(NF4 frozen base + GSE-QCD LoRA adapters). Non-linear ops (norms, softmax,
rope, activations) stay in 16/32-bit per the paper's Sec. 6.

Param convention: each module returns a *pair* of trees ``(frozen, train)``
with mirrored structure; adapter leaves live only in ``train``. Layer stacks
are built by vmapping the per-layer init (leaves gain a leading L axis) and
consumed with ``jax.lax.scan``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lora import init_gsq_linear, apply_gsq_linear
from repro.core.policy import QuantPolicy
from repro.models.config import ModelConfig
from repro.distributed.sharding import shard

# --------------------------------------------------------------------------
# Norms / positions
# --------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    # statistics in fp32 (cheap: reduce output is (..., 1)); the fat
    # normalize/scale multiplies stay in the stream dtype — saves two
    # full-width f32 passes per norm (§Perf iter 8). The rsqrt factor is
    # exact-cast to bf16 (~0.4% relerr), well below GSE-6 quant noise.
    xf32 = x.astype(jnp.float32)
    var = jnp.mean(xf32 * xf32, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * r * p["scale"].astype(x.dtype)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"]
            + p["bias"]).astype(x.dtype)


def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    return layernorm_init(d) if cfg.family in ("encdec",) else rmsnorm_init(d)


def norm_apply(cfg: ModelConfig, p, x):
    if cfg.family in ("encdec",):
        return layernorm(p, x, cfg.norm_eps)
    return rmsnorm(p, x, cfg.norm_eps)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, D); positions: (B, T) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (B, T, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int) -> jax.Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# Attention (GQA, optional qk-norm / qkv-bias / sliding window / KV cache)
# --------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, policy: QuantPolicy, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    fz, tr = {}, {}
    fz["wq"], tr["wq"] = init_gsq_linear(kq, d, cfg.n_heads * hd, policy)
    fz["wk"], tr["wk"] = init_gsq_linear(kk, d, cfg.n_kv_heads * hd, policy)
    fz["wv"], tr["wv"] = init_gsq_linear(kv, d, cfg.n_kv_heads * hd, policy)
    fz["wo"], tr["wo"] = init_gsq_linear(ko, cfg.n_heads * hd, d, policy)
    if cfg.qkv_bias and not cross:
        fz["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        fz["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        fz["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    if cfg.qk_norm:
        fz["q_norm"] = rmsnorm_init(hd)
        fz["k_norm"] = rmsnorm_init(hd)
    return fz, tr


def _project_qkv(fz, tr, x, cfg: ModelConfig, policy):
    hd = cfg.resolved_head_dim
    b, t, _ = x.shape
    q = apply_gsq_linear(fz["wq"], tr["wq"], x, policy)
    k = apply_gsq_linear(fz["wk"], tr["wk"], x, policy)
    v = apply_gsq_linear(fz["wv"], tr["wv"], x, policy)
    if "bq" in fz:
        q = q + fz["bq"].astype(q.dtype)
        k = k + fz["bk"].astype(k.dtype)
        v = v + fz["bv"].astype(v.dtype)
    q = q.reshape(b, t, cfg.n_heads, hd)
    k = k.reshape(b, t, cfg.n_kv_heads, hd)
    v = v.reshape(b, t, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(fz["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(fz["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _kv_write(buf, rows, pos):
    """Write ``rows`` (B, t, ...) into the sequence axis of ``buf``
    (B, S, ...) at ``pos`` — a shared scalar index (one dynamic update
    slice, the static-batch path) or a per-sequence (B,) vector (vmapped
    per-row writes: ragged serving batches land each row at its own
    offset)."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jax.lax.dynamic_update_slice(
            buf, rows.astype(buf.dtype), (0, pos) + (0,) * (buf.ndim - 2))
    return jax.vmap(
        lambda bb, rr, pp: jax.lax.dynamic_update_slice(
            bb, rr.astype(bb.dtype), (pp,) + (0,) * (bb.ndim - 1))
    )(buf, rows, pos)


def attn_apply(fz, tr, x, cfg: ModelConfig, policy: QuantPolicy, *,
               positions: jax.Array, mask_info,
               layer_cache: Optional[dict] = None,
               ring_buffer: bool = False,
               use_rope: bool = True) -> Tuple[jax.Array, Optional[dict]]:
    """Self-attention. ``mask_info`` is an attention.MaskInfo (structural
    mask — no (T,S) materialization). ``layer_cache`` (decode): dict with
    k/v (B,S,Kv,D) and index (scalar or per-sequence (B,) vector) — or the
    **packed** planes ``k_words``/``k_exp``/``v_words``/``v_exp``
    (row-planar GSE storage), in which case the new token is
    quantized+packed and written in place and attention runs fused over
    the packed cache (the cache is never materialized unpacked) — or the
    **paged** pool planes ``kp_words``/``kp_exp``/``vp_words``/``vp_exp``
    + ``pages`` (continuous-batching serving: writes resolve through the
    page table; attention walks each sequence's pages). Returns updated
    cache."""
    from repro.models.attention import attention, packed_attention
    b, t, _ = x.shape
    q, k, v = _project_qkv(fz, tr, x, cfg, policy)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    new_cache = None
    if layer_cache is not None and "kp_words" in layer_cache:
        from repro.kernels.ops import quant_pack_kv_rows
        from repro.kernels.flash_attention_packed import kv_row_bits
        from repro.models.attention import paged_attention
        # paged serving path: packed planes live in a page pool
        # (P, page, Kv, ·); this step's logical position resolves through
        # the slot's page-table row to a (physical page, in-page slot)
        # write. Inactive batch rows have every logical page pointed at
        # the trash page, so their stale (still-advancing, clip-indexed)
        # writes never touch allocated pages.
        assert t == 1, "paged cache writes are decode-only (t == 1)"
        kpw, kpe = layer_cache["kp_words"], layer_cache["kp_exp"]
        vpw, vpe = layer_cache["vp_words"], layer_cache["vp_exp"]
        pages = layer_cache["pages"]                    # (B, maxp) int32
        idx = jnp.asarray(layer_cache["index"], jnp.int32)  # (B,)
        d = cfg.resolved_head_dim
        page = kpw.shape[1]
        bits = kv_row_bits(kpw.shape[-1], d)
        group = d // kpe.shape[-1]
        nkw, nke = quant_pack_kv_rows(k, bits, group)   # (B, 1, Kv, ·)
        nvw, nve = quant_pack_kv_rows(v, bits, group)
        lp = jnp.minimum(idx // page, pages.shape[1] - 1)
        slot = idx % page
        phys = jnp.take_along_axis(pages, lp[:, None], axis=1)[:, 0]

        def wr(pool, rows):
            return pool.at[phys, slot].set(rows[:, 0])
        kpw, kpe = wr(kpw, nkw), wr(kpe, nke)
        vpw, vpe = wr(vpw, nvw), wr(vpe, nve)
        new_cache = dict(layer_cache, kp_words=kpw, kp_exp=kpe,
                         vp_words=vpw, vp_exp=vpe, index=idx + t)
        # quantize-after-attend, exactly as on the planar packed path: the
        # pool stores the quantized rows; the current token rides the fp
        # tail (packed positions >= each row's offset are masked)
        # reads may narrow: cfg.kv_active_bits takes a static plane prefix
        # of every page; the cache's per-sequence "kv_trunc" vector (B,)
        # shifts extra planes below that per lane (mixed-kv_bits serving).
        # Writes always quantize at the pool's stored width.
        o = paged_attention(q, kpw, kpe, vpw, vpe, pages, mask_info,
                            k_tail=k, v_tail=v, k_chunk=cfg.attn_k_chunk,
                            kv_active_bits=cfg.kv_active_bits,
                            kv_trunc=layer_cache.get("kv_trunc"))
    elif layer_cache is not None and "k_words" in layer_cache:
        from repro.kernels.ops import quant_pack_kv_rows
        kw, ke = layer_cache["k_words"], layer_cache["k_exp"]
        vw, ve = layer_cache["v_words"], layer_cache["v_exp"]
        idx = layer_cache["index"]
        d = cfg.resolved_head_dim
        from repro.kernels.flash_attention_packed import kv_row_bits
        bits = kv_row_bits(kw.shape[-1], d)       # static, from the planes
        group = d // ke.shape[-1]
        # in-place packed append: quantize+pack only the new token's rows
        # (fused kernel path for 32-aligned head dims), one word-row write
        nkw, nke = quant_pack_kv_rows(k, bits, group)
        nvw, nve = quant_pack_kv_rows(v, bits, group)
        write = (idx % kw.shape[1]) if ring_buffer else idx
        kw = _kv_write(kw, nkw, write)
        ke = _kv_write(ke, nke, write)
        vw = _kv_write(vw, nvw, write)
        ve = _kv_write(ve, nve, write)
        new_cache = dict(layer_cache, k_words=kw, k_exp=ke, v_words=vw,
                         v_exp=ve, index=idx + t)
        # quantize-after-attend: the cache stores the quantized rows, but
        # the current token attends to its own k/v at full precision (the
        # fp tail) — token-identical to the round-trip A/B path, which
        # only quantizes the new rows at the post-step re-pack. Not under
        # ring_buffer: the tail's history mask works on absolute positions
        # (kpos < q_offset) and cannot exclude a wrapped write slot, so the
        # current token would be attended twice — ring mode keeps attending
        # its just-quantized rows instead.
        tails = {} if ring_buffer else dict(k_tail=k, v_tail=v)
        # reads may narrow (plane-prefix view / per-seq trunc) while the
        # appends above stay at the cache's stored width
        o = packed_attention(q, kw, ke, vw, ve, mask_info,
                             k_chunk=cfg.attn_k_chunk,
                             kv_active_bits=cfg.kv_active_bits,
                             kv_trunc=layer_cache.get("kv_trunc"), **tails)
    else:
        if layer_cache is not None:
            ck, cv, idx = (layer_cache["k"], layer_cache["v"],
                           layer_cache["index"])
            s_max = ck.shape[1]
            write = (idx % s_max) if ring_buffer else idx
            ck = _kv_write(ck, k, write)
            cv = _kv_write(cv, v, write)
            k, v = ck, cv
            new_cache = dict(layer_cache, k=ck, v=cv, index=idx + t)
        o = attention(q, k, v, mask_info,
                      q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    o = shard(o, "batch", None, "heads", None)
    y = apply_gsq_linear(fz["wo"], tr["wo"], o.reshape(b, t, -1), policy)
    return y, new_cache


def cross_attn_apply(fz, tr, x, enc_kv, cfg: ModelConfig,
                     policy: QuantPolicy) -> jax.Array:
    """Cross-attention (whisper decoder). enc_kv: precomputed (k, v) from
    the encoder output — (B, S_enc, Kv, D) each — or the 4-tuple of
    row-planar packed planes (k_words, k_exp, v_words, v_exp) when the
    decode cache is packed (attends fused, no unpacked cross cache)."""
    from repro.models.attention import attention, packed_attention, MaskInfo
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    q = apply_gsq_linear(fz["wq"], tr["wq"], x, policy).reshape(
        b, t, cfg.n_heads, hd)
    if len(enc_kv) == 4:
        o = packed_attention(q, *enc_kv, MaskInfo(causal=False),
                             k_chunk=cfg.attn_k_chunk)
    else:
        k, v = enc_kv
        o = attention(q, k, v, MaskInfo(causal=False),
                      q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    return apply_gsq_linear(fz["wo"], tr["wo"], o.reshape(b, t, -1), policy)


def cross_kv(fz, tr, enc_out, cfg: ModelConfig, policy: QuantPolicy):
    """Project encoder output to cross-attention k/v once per sequence."""
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = apply_gsq_linear(fz["wk"], tr["wk"], enc_out, policy).reshape(
        b, s, cfg.n_kv_heads, hd)
    v = apply_gsq_linear(fz["wv"], tr["wv"], enc_out, policy).reshape(
        b, s, cfg.n_kv_heads, hd)
    return k, v


# --------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain GELU)
# --------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, policy: QuantPolicy,
             d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    fz, tr = {}, {}
    if cfg.act in ("silu", "gelu"):          # gated
        fz["w_gate"], tr["w_gate"] = init_gsq_linear(k1, d, f, policy)
        fz["w_up"], tr["w_up"] = init_gsq_linear(k2, d, f, policy)
    else:                                    # plain MLP (whisper)
        fz["w_up"], tr["w_up"] = init_gsq_linear(k2, d, f, policy)
    fz["w_down"], tr["w_down"] = init_gsq_linear(k3, f, d, policy)
    return fz, tr


def mlp_apply(fz, tr, x, cfg: ModelConfig, policy: QuantPolicy):
    if cfg.act in ("silu", "gelu"):
        g = apply_gsq_linear(fz["w_gate"], tr["w_gate"], x, policy)
        u = apply_gsq_linear(fz["w_up"], tr["w_up"], x, policy)
        act = jax.nn.silu if cfg.act == "silu" else partial(
            jax.nn.gelu, approximate=True)
        h = act(g.astype(jnp.float32)).astype(u.dtype) * u
    else:
        u = apply_gsq_linear(fz["w_up"], tr["w_up"], x, policy)
        h = jax.nn.gelu(u.astype(jnp.float32), approximate=True).astype(u.dtype)
    h = shard(h, "batch", None, "ff")
    return apply_gsq_linear(fz["w_down"], tr["w_down"], h, policy)


# --------------------------------------------------------------------------
# MoE with sort-based (FLOPs-faithful) dispatch
# --------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig, policy: QuantPolicy):
    """Experts: frozen NF4, GSE-QCD compute, no per-expert adapters (see
    DESIGN §6 — adapter placement). Router: frozen bf16 (precision-sensitive,
    negligible size)."""
    from repro.core.nf4 import nf4_quantize
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    kr, k1, k2, k3 = jax.random.split(key, 4)
    scale = d ** -0.5
    fz = {
        "router": (jax.random.normal(kr, (d, e), jnp.float32) * scale
                   ).astype(jnp.float32),
        "w_gate": nf4_quantize(jax.random.normal(k1, (e, d, f)) * scale),
        "w_up": nf4_quantize(jax.random.normal(k2, (e, d, f)) * scale),
        "w_down": nf4_quantize(jax.random.normal(k3, (e, f, d)) * (f ** -0.5)),
    }
    return fz, {}


def _quantized_bmm(x, w, policy: QuantPolicy):
    """(E, C, K) @ (E, K, N) with QCD semantics per expert."""
    if policy.fmt == "none":
        return jnp.einsum("eck,ekn->ecn", x, w)
    from repro.core.qcd import quantized_matmul
    f = partial(quantized_matmul, a_bits=policy.a_bits, w_bits=policy.w_bits,
                g_bits=policy.g_bits, group_size=policy.group_size,
                residuals_packed=policy.residuals_packed,
                residual_bits=policy.residual_bits, int_mac=policy.int_mac)
    return jax.vmap(lambda a, b: f(a, b))(x, w)


def moe_apply(fz, tr, x, cfg: ModelConfig, policy: QuantPolicy):
    """Top-k routed MoE via sort-based capacity dispatch.

    Dispatch/combine are gathers/scatters (memory ops, no FLOPs inflation);
    the expert GEMMs are grouped (E, C, d) x (E, d, f) batched matmuls that
    shard over the `experts` logical axis (EP on the model mesh axis).
    """
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    f = cfg.moe_d_ff or cfg.d_ff
    n_tok = b * t
    xf = x.reshape(n_tok, d)

    logits = (xf.astype(jnp.float32) @ fz["router"])            # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                        # (N, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # capacity floor covers the decode regime (few tokens, every copy must
    # land) without inflating the training buffers
    cap = int(max(round(n_tok * k / e * cfg.capacity_factor),
                  min(n_tok, 16), 1))
    flat_e = eidx.reshape(-1)                                   # (N*k,)
    # Rank each (token, slot) within its expert via a one-hot cumsum in
    # token order — equivalent to the stable-argsort rank but with NO
    # global sort (a multi-device sort is an all-to-all storm; §Perf MoE
    # iteration 1). The cumsum shards cleanly along the token axis.
    onehot = (flat_e[:, None] == jnp.arange(e)[None, :]).astype(jnp.int32)
    pos_all = jnp.cumsum(onehot, axis=0)                        # (N*k, E)
    pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0] - 1
    keep = pos < cap
    # overflow copies clamp to slot 0 with a zero contribution (scatter-ADD
    # keeps slot 0 exact); buffer stays (E, C, ...) divisible so the expert
    # axis shards instead of replicating a flat (E*C+1,) scratch
    # (§Perf MoE iteration 2)
    buf_slot = jnp.where(keep, flat_e * cap + pos, 0)
    tok_of_slot = jnp.arange(n_tok * k) // k                    # token index

    contrib = xf[tok_of_slot] * keep[:, None].astype(xf.dtype)
    xb = jnp.zeros((e * cap, d), x.dtype).at[buf_slot].add(contrib)
    xe = xb.reshape(e, cap, d)
    xe = shard(xe, "experts", None, None)

    wg = fz["w_gate"].dequantize(x.dtype)
    wu = fz["w_up"].dequantize(x.dtype)
    wd = fz["w_down"].dequantize(x.dtype)
    wg = shard(wg, "experts", "w_embed", None)
    wu = shard(wu, "experts", "w_embed", None)
    wd = shard(wd, "experts", None, "w_embed")
    act = jax.nn.silu if cfg.act == "silu" else partial(jax.nn.gelu,
                                                        approximate=True)
    g = _quantized_bmm(xe, wg, policy)
    u = _quantized_bmm(xe, wu, policy)
    h = act(g.astype(jnp.float32)).astype(u.dtype) * u
    h = shard(h, "experts", None, "ff")
    ye = _quantized_bmm(h, wd, policy)                          # (E, C, d)

    # combine: gather each kept (token, expert) copy, weight, scatter-add
    yb = ye.reshape(e * cap, d)
    # buf_slot/tok_of_slot are already in token order under the cumsum rank;
    # dropped copies gather slot 0 but are masked to zero weight
    w_copy = (gate.reshape(-1) * keep.astype(gate.dtype))[:, None]
    per_copy = yb[buf_slot] * w_copy.astype(ye.dtype)
    y = jnp.zeros((n_tok, d), ye.dtype).at[tok_of_slot].add(per_copy)
    return y.reshape(b, t, d)
