"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax use;
tests and benches see the 1 real CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def dp_size(mesh) -> int:
    n = mesh.shape.get("data", 1)
    return n * mesh.shape.get("pod", 1)


def tp_size(mesh) -> int:
    return mesh.shape.get("model", 1)
