"""The assigned (architecture x input-shape) grid: 10 archs x 4 shapes.

Per cell this module provides:
  * ``cell_plan(arch, shape)`` — mode (train/prefill/decode), grad-accum
    target, skip status + reason (DESIGN §8),
  * ``input_specs(cfg, shape, mesh)`` — ShapeDtypeStruct stand-ins for every
    lowered input (weak-type-correct, shardable, no allocation),
  * ``abstract_state(cfg, policy, shape, mesh, rules)`` — eval_shape'd
    params / optimizer / cache trees with their shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, all_arch_names
from repro.core.policy import QuantPolicy
from repro.models.config import ModelConfig
from repro.distributed.sharding import ShardingRules, resolve_pspec

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, mode="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, mode="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, mode="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, mode="decode"),
}

# grad-accumulation targets per arch for train_4k (sized so per-device live
# activations stay < ~4 GB with remat; DESIGN §5)
ACCUM = {
    "whisper_small": 4, "llava_next_34b": 16, "granite_3_2b": 4,
    "qwen2_1_5b": 2, "gemma_7b": 4, "qwen3_14b": 8, "mamba2_2_7b": 8,
    "granite_moe_1b_a400m": 2, "arctic_480b": 16, "hymba_1_5b": 4,
    "llama2_7b": 8,
}

# archs that run long_500k (sub-quadratic decode state) — DESIGN §8
LONG_OK = {"mamba2_2_7b", "hymba_1_5b"}

# big archs use FSDP rules (weight d_model dims sharded over data)
FSDP_ARCHS = {"llava_next_34b", "arctic_480b", "qwen3_14b"}


@dataclasses.dataclass(frozen=True)
class CellPlan:
    arch: str
    shape: str
    mode: str                 # train | prefill | decode
    seq_len: int
    global_batch: int
    accum: int
    skip: bool = False
    skip_reason: str = ""


def cell_plan(arch: str, shape: str, mesh=None) -> CellPlan:
    info = SHAPES[shape]
    mode = info["mode"]
    skip, reason = False, ""
    if shape == "long_500k" and arch not in LONG_OK:
        skip = True
        reason = ("pure full-attention arch — long_500k assigned only to "
                  "SSM/hybrid archs (DESIGN §8)")
    accum = 1
    if mode == "train":
        dp = 1
        if mesh is not None:
            dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        accum = min(ACCUM.get(arch, 4), max(info["global_batch"] // dp, 1))
    return CellPlan(arch, shape, mode, info["seq_len"], info["global_batch"],
                    accum, skip, reason)


def rules_for(arch: str, mesh) -> ShardingRules:
    multi = "pod" in getattr(mesh, "shape", {})
    if arch in FSDP_ARCHS:
        return ShardingRules.fsdp(multi_pod=multi)
    return ShardingRules() if multi else ShardingRules.single_pod()


def arch_cfg(arch: str, shape: Optional[str] = None) -> ModelConfig:
    cfg = get_config(arch)
    # big-head archs need smaller attention blocks (DESIGN §5 memory table)
    if cfg.n_heads * cfg.resolved_head_dim >= 7168:
        cfg = dataclasses.replace(cfg, attn_q_chunk=256, attn_k_chunk=512)
    if cfg.is_encoder_decoder:
        # pad whisper's 1500-frame grid to 1536 so flash chunking divides
        cfg = dataclasses.replace(cfg, encoder_len=1536)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStructs for the *batch* argument of the lowered step."""
    info = SHAPES[shape]
    b, s = info["global_batch"], info["seq_len"]
    mode = info["mode"]
    if mode == "train":
        batch = {
            "labels": _sds((b, s), jnp.int32),
            "loss_mask": _sds((b, s), jnp.float32),
        }
        if cfg.frontend == "vlm":
            batch["inputs_embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = _sds((b, s), jnp.int32)
        if cfg.is_encoder_decoder:
            batch["frames"] = _sds((b, cfg.encoder_len, cfg.d_model),
                                   jnp.bfloat16)
        return batch
    if mode == "prefill":
        batch = {}
        if cfg.frontend == "vlm":
            batch["inputs_embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = _sds((b, s), jnp.int32)
        if cfg.is_encoder_decoder:
            batch["frames"] = _sds((b, cfg.encoder_len, cfg.d_model),
                                   jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len cache
    return {"tokens": _sds((b, 1), jnp.int32)}


def batch_shardings(batch_specs: dict, mesh, rules: ShardingRules):
    out = {}
    for k, v in batch_specs.items():
        logical = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh,
                               resolve_pspec(v.shape, logical, mesh, rules))
    return out


def all_cells():
    for arch in all_arch_names():
        for shape in SHAPES:
            yield arch, shape
