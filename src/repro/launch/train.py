"""Training launcher CLI — the entry point a cluster scheduler invokes.

    PYTHONPATH=src python -m repro.launch.train --arch granite_3_2b \
        --bits 6 --rank 64 --steps 100 --reduced

``--reduced`` runs the CPU-scale config; without it the full config is
built (requires real accelerators). Handles resume-from-checkpoint and
preemption automatically via TrainingRunner.
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import get_config, reduced_config
from repro.core.policy import QuantPolicy
from repro.data.pipeline import DataConfig
from repro.models import model as M
from repro.optim.adamw8bit import AdamW8bit
from repro.train.runner import RunnerConfig, TrainingRunner
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b")
    ap.add_argument("--bits", type=int, default=6)
    ap.add_argument("--rank", type=int, default=64)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=1e-5)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    policy = QuantPolicy.gsq(args.bits, rank=args.rank)
    frozen, train = M.init_model(jax.random.PRNGKey(args.seed), cfg, policy)
    runner = TrainingRunner(
        cfg, policy,
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch),
        AdamW8bit(lr=args.lr),
        TrainConfig(accum_steps=args.accum),
        RunnerConfig(total_steps=args.steps,
                     checkpoint_every=args.ckpt_every,
                     checkpoint_dir=args.ckpt_dir),
        frozen=frozen, train=train)
    runner.install_signal_handlers()
    if runner.maybe_resume():
        logging.info("resumed at step %d", runner.step)
    hist = runner.run()
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f} at step {runner.step}")


if __name__ == "__main__":
    main()
