import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production mesh(es) and extract memory / cost / collective analysis.

MUST be invoked as its own process (the XLA_FLAGS line above runs before any
jax import — 512 placeholder CPU devices). Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite_3_2b \
        --shape train_4k --mesh single --out results/
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as R
from repro.core.policy import QuantPolicy
from repro.distributed.params import (infer_param_shardings,
                                      opt_state_pspecs)
from repro.distributed.sharding import use_sharding
from repro.launch import cells as CELLS
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim.adamw8bit import AdamW8bit
from repro.serve import engine as E
from repro.train import step as TS


def _abstract(fn, *args, **kwargs):
    return jax.eval_shape(fn, *args, **kwargs)


def lower_cell(arch: str, shape: str, multi_pod: bool,
               policy: QuantPolicy = None, compress: bool = None,
               verbose: bool = True):
    """Lower + compile one cell; returns a result dict."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = CELLS.cell_plan(arch, shape, mesh)
    if plan.skip:
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": plan.skip_reason}
    cfg = CELLS.arch_cfg(arch, shape)
    rules = CELLS.rules_for(arch, mesh)
    # FSDP archs keep the flat NF4 dequant: the per-layer weight gather IS
    # the FSDP pattern, and the shape-preserving path regressed memory for
    # them (§Perf llava iteration). TP archs use the sharded shaped path.
    if arch in CELLS.FSDP_ARCHS:
        os.environ["REPRO_NF4_FLAT_DEQUANT"] = "1"
    else:
        os.environ.pop("REPRO_NF4_FLAT_DEQUANT", None)
    policy = policy or QuantPolicy.gsq(6, rank=64)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()

    key = jax.random.PRNGKey(0)
    fz_abs, tr_abs = _abstract(
        partial(M.init_model, cfg=cfg, policy=policy), key)
    fz_sh = infer_param_shardings(fz_abs, mesh, rules)
    tr_sh = infer_param_shardings(tr_abs, mesh, rules)
    batch_specs = CELLS.input_specs(cfg, shape)
    batch_sh = CELLS.batch_shardings(batch_specs, mesh, rules)
    info = CELLS.SHAPES[shape]
    b, s = info["global_batch"], info["seq_len"]

    with use_sharding(mesh, rules):
        if plan.mode == "train":
            opt = AdamW8bit(lr=1e-5)
            opt_abs = _abstract(opt.init, tr_abs)
            opt_sh = jax.tree.map(
                lambda sp: NamedSharding(mesh, sp),
                opt_state_pspecs(opt_abs, mesh, rules))
            # XLA SPMD partitioner CHECK-fails partitioning the MoE
            # dispatch gather/scatter inside a manual-pod shard_map
            # (EXPERIMENTS §Dry-run note); cross-pod compression is
            # disabled for the MoE archs and uses plain SPMD reduction.
            default_comp = multi_pod and arch not in (
                "arctic_480b", "granite_moe_1b_a400m")
            use_comp = (compress if compress is not None else default_comp)
            tcfg = TS.TrainConfig(accum_steps=plan.accum,
                                  compress_pod_grads=use_comp)
            n_pods = mesh.shape.get("pod", 1)
            res_abs = _abstract(partial(TS.init_residuals, n_pods=n_pods),
                                tr_abs) if use_comp else \
                jax.tree.map(lambda p: jax.ShapeDtypeStruct((0,),
                                                            jnp.float32),
                             tr_abs)
            res_sh = jax.tree.map(
                lambda leaf: NamedSharding(
                    mesh, P("pod") if (use_comp and len(leaf.shape) > 0)
                    else P()),
                res_abs)
            step_fn = TS.make_train_step(cfg, policy, opt, tcfg, mesh)
            jfn = jax.jit(step_fn,
                          in_shardings=(fz_sh, tr_sh, opt_sh, res_sh,
                                        batch_sh),
                          donate_argnums=(1, 2, 3))
            lowered = jfn.lower(fz_abs, tr_abs, opt_abs, res_abs,
                                batch_specs)
            tokens = b * s
            # 6*N*D already covers fwd(2ND) + bwd(4ND)
            mflops = R.model_flops_train(cfg, tokens)
        elif plan.mode == "prefill":
            cache_abs = _abstract(partial(
                E.init_decode_cache, cfg, b, s,
                enc_len=cfg.encoder_len if cfg.is_encoder_decoder else None))
            cache_sh = E.cache_shardings(
                cfg, b, s, mesh, rules,
                enc_len=cfg.encoder_len if cfg.is_encoder_decoder else None)
            cache_sh = {k: cache_sh.get(k, NamedSharding(mesh, P()))
                        for k in cache_abs}
            fn = partial(E.prefill, cfg=cfg, policy=policy)
            jfn = jax.jit(fn, in_shardings=(fz_sh, tr_sh, batch_sh,
                                            cache_sh),
                          donate_argnums=(3,))
            lowered = jfn.lower(fz_abs, tr_abs, batch_specs, cache_abs)
            mflops = 2.0 * cfg.active_param_count() * b * s
        else:  # decode
            max_len = s
            use_kv = cfg.uses_attention
            cache_abs = _abstract(partial(
                E.init_decode_cache, cfg, b, max_len,
                enc_len=cfg.encoder_len if cfg.is_encoder_decoder else None))
            cache_sh = E.cache_shardings(
                cfg, b, max_len, mesh, rules,
                enc_len=cfg.encoder_len if cfg.is_encoder_decoder else None)
            cache_sh = {k: cache_sh.get(k, NamedSharding(mesh, P()))
                        for k in cache_abs}
            tok_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            tok_sh = CELLS.batch_shardings(
                {"tokens": tok_abs}, mesh, rules)["tokens"]
            fn = partial(E.decode_step, cfg=cfg, policy=policy)
            jfn = jax.jit(fn, in_shardings=(fz_sh, tr_sh, tok_sh, cache_sh),
                          donate_argnums=(3,))
            lowered = jfn.lower(fz_abs, tr_abs, tok_abs, cache_abs)
            mflops = R.model_flops_decode(cfg, b, s)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    roof, coll = R.from_compiled(compiled, chips, model_flops=mflops,
                                 hlo_text=hlo)
    mem = R.memory_analysis_dict(compiled)
    result = {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "chips": chips, "mode": plan.mode,
        "accum": plan.accum,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "roofline": roof.to_dict(),
        "collectives": coll.to_dict(),
        "memory_analysis": mem,
        "policy": policy.label(),
        "hlo_bytes": len(hlo),
    }
    if verbose:
        print(json.dumps(result, indent=1))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--bits", type=int, default=6)
    ap.add_argument("--rank", type=int, default=64)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = list(CELLS.all_cells()) if args.all else [(args.arch,
                                                       args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    policy = QuantPolicy.gsq(args.bits, rank=args.rank)
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"skip existing {tag}")
                continue
            print(f"=== {tag} ===", flush=True)
            try:
                res = lower_cell(arch, shape, multi, policy=policy,
                                 verbose=False)
            except Exception as e:
                res = {"arch": arch, "shape": shape,
                       "mesh": "multi" if multi else "single",
                       "status": "error", "error": str(e)[-2000:],
                       "traceback": traceback.format_exc()[-4000:]}
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            print(f"  -> {res['status']}"
                  + (f" compile={res.get('compile_s')}s dominant="
                     f"{res.get('roofline', {}).get('dominant')}"
                     if res["status"] == "ok" else
                     f" {res.get('reason', res.get('error', ''))[:200]}"),
                  flush=True)


if __name__ == "__main__":
    main()
