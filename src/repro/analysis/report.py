"""Aggregate dry-run JSONs into the EXPERIMENTS.md §Dry-run / §Roofline
tables. ``python -m repro.analysis.report results/dryrun``"""
from __future__ import annotations

import json
import os
import sys


def load(dirname: str):
    cells = {}
    for f in sorted(os.listdir(dirname)):
        if not f.endswith(".json"):
            continue
        d = json.load(open(os.path.join(dirname, f)))
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b / 1e12:.2f}T"
    if b >= 1e9:
        return f"{b / 1e9:.2f}G"
    return f"{b / 1e6:.1f}M"


def dryrun_table(cells) -> str:
    rows = ["| arch | shape | mesh | status | compile_s | args/dev | temp/dev"
            " | collectives (AR/AG/RS/A2A/CP) |",
            "|---|---|---|---|---|---|---|---|"]
    for (a, s, m), d in sorted(cells.items()):
        if d["status"] != "ok":
            rows.append(f"| {a} | {s} | {m} | {d['status']}: "
                        f"{d.get('reason', d.get('error', ''))[:60]} | | | | |")
            continue
        ma = d.get("memory_analysis", {})
        c = d["collectives"]["count_by_kind"]
        cc = "/".join(str(int(c.get(k, 0))) for k in
                      ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"))
        rows.append(
            f"| {a} | {s} | {m} | ok | {d['compile_s']} | "
            f"{fmt_bytes(ma.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(ma.get('temp_size_in_bytes', 0))} | {cc} |")
    return "\n".join(rows)


def roofline_table(cells, mesh="single") -> str:
    rows = ["| arch | shape | compute_s | memory_s | collective_s | "
            "dominant | MODEL_FLOPs | useful_ratio | roofline_frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), d in sorted(cells.items()):
        if m != mesh or d["status"] != "ok":
            continue
        r = d["roofline"]
        rows.append(
            f"| {a} | {s} | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['model_flops_global']:.2e} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def pick_hillclimb(cells):
    """worst roofline fraction / most collective-bound / most
    paper-representative among single-mesh train cells."""
    singles = {k: v for k, v in cells.items()
               if k[2] == "single" and v["status"] == "ok"}
    worst = min(singles.items(),
                key=lambda kv: kv[1]["roofline"]["roofline_fraction"])
    coll = max(singles.items(),
               key=lambda kv: (kv[1]["roofline"]["collective_s"]
                               / max(kv[1]["roofline"]["bound_s"]
                                     if "bound_s" in kv[1]["roofline"]
                                     else max(kv[1]["roofline"]["compute_s"],
                                              kv[1]["roofline"]["memory_s"],
                                              kv[1]["roofline"]
                                              ["collective_s"]), 1e-30)))
    return worst[0], coll[0]


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    cells = load(d)
    ok = sum(1 for c in cells.values() if c["status"] == "ok")
    sk = sum(1 for c in cells.values() if c["status"] == "skipped")
    print(f"cells: {len(cells)} ok={ok} skipped={sk} "
          f"err={len(cells) - ok - sk}\n")
    print("## Dry-run\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(cells, "single"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(cells, "multi"))
    w, c = pick_hillclimb(cells)
    print(f"\nworst-fraction cell: {w}\nmost-collective-bound: {c}")


if __name__ == "__main__":
    main()
