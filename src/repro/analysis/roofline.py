"""Roofline-term derivation from a compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

FLOPs / bytes come from ``compiled.cost_analysis()``. Collective bytes are
parsed from the post-SPMD optimized HLO (``compiled.as_text()``): for each
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute we
sum the payload (result-shape bytes; for reduce-scatter the operand shape),
which approximates per-device wire bytes of one ring pass.

Hardware model (TPU v5e target): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. INT8 MXU peak is 2x bf16 (394 TOPS) — the GSE int8 path
uses ``int8_fraction`` to credit it.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

PEAK_BF16 = 197e12          # FLOP/s per chip
PEAK_INT8 = 394e12          # int8 MAC ops/s per chip
HBM_BW = 819e9              # bytes/s per chip
LINK_BW = 50e9              # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'f32[128,1024]' or a tuple."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict
    total_bytes: int

    def to_dict(self):
        return {"bytes_by_kind": self.bytes_by_kind,
                "count_by_kind": self.count_by_kind,
                "total_bytes": self.total_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum payload bytes of every collective op in optimized HLO text."""
    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    # one instruction per line in HLO text: "%name = <shape> opcode(...)"
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+"
                     r"([\w-]+)\(", s)
        if not m:
            continue
        shape_str, opcode = m.group(1), m.group(2)
        # normalize: 'all-reduce-start' etc count as their base op
        base = None
        for c in _COLLECTIVES:
            if opcode == c or opcode.startswith(c + "-"):
                base = c
                break
        if base is None:
            continue
        nbytes = _shape_bytes(shape_str)
        bytes_by[base] += nbytes
        count_by[base] += 1
    total = sum(bytes_by.values())
    return CollectiveStats(bytes_by, count_by, total)


@dataclasses.dataclass
class Roofline:
    """All HLO-derived quantities are PER DEVICE — the post-SPMD optimized
    module is the per-device program. ``model_flops`` is GLOBAL (6·N·D·tokens
    over the whole batch) and is divided by ``chips`` where compared."""
    flops: float                # per-device HLO FLOPs
    hbm_bytes: float            # per-device HLO bytes accessed
    collective_bytes: float     # per-device wire bytes (summed payloads)
    chips: int
    model_flops: float = 0.0    # GLOBAL 6*N*D (or 6*N_active*D)
    int8_fraction: float = 0.0  # fraction of FLOPs on the int8 MXU path
    xla_cost_flops: float = 0.0     # XLA's own (while-body-once) numbers,
    xla_cost_bytes: float = 0.0     # kept for cross-checking
    while_trips: list = dataclasses.field(default_factory=list)

    @property
    def compute_s(self) -> float:
        peak = PEAK_BF16 * (1 - self.int8_fraction) \
            + PEAK_INT8 * self.int8_fraction
        return self.flops / peak

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """(global model flops / chips) / per-device HLO flops — how much of
        compiled compute is useful model math (catches remat/redundancy)."""
        if not self.flops:
            return 0.0
        return (self.model_flops / self.chips) / self.flops

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Score axis: time the ideal machine needs for the useful model
        FLOPs vs the time the compiled program is bound by — i.e. achieved
        fraction of bf16 roofline."""
        if not self.model_flops or not self.bound_s:
            return 0.0
        ideal = self.model_flops / (self.chips * PEAK_BF16)
        return ideal / self.bound_s

    def to_dict(self):
        return {
            "flops_per_device": self.flops, "hbm_bytes_per_device":
            self.hbm_bytes, "collective_bytes_per_device":
            self.collective_bytes, "chips": self.chips,
            "model_flops_global": self.model_flops,
            "int8_fraction": self.int8_fraction,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "xla_cost_flops": self.xla_cost_flops,
            "xla_cost_bytes": self.xla_cost_bytes,
            "n_while_loops": len(self.while_trips),
        }


def from_compiled(compiled, chips: int, model_flops: float = 0.0,
                  int8_fraction: float = 0.0,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Preferred path: trip-count-aware HLO walk (hlo_walk). XLA's own
    cost_analysis counts while bodies once — useless under scan-over-layers
    — but is retained in the result dict for cross-checking."""
    from repro.analysis import hlo_walk
    cost = compiled.cost_analysis()
    if isinstance(cost, list):         # older API returns [dict]
        cost = cost[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    w = hlo_walk.walk(text)
    coll = CollectiveStats(
        {k: float(v) for k, v in w.collective_bytes.items()},
        {k: float(v) for k, v in w.collective_counts.items()},
        float(w.total_collective_bytes))
    if int8_fraction == 0.0 and w.flops > 0:
        int8_fraction = w.int8_flops / w.flops
    roof = Roofline(flops=float(w.flops), hbm_bytes=float(w.hbm_bytes),
                    collective_bytes=float(w.total_collective_bytes),
                    chips=chips, model_flops=model_flops,
                    int8_fraction=int8_fraction)
    roof.xla_cost_flops = float(cost.get("flops", 0.0))
    roof.xla_cost_bytes = float(cost.get("bytes accessed", 0.0))
    roof.while_trips = list(w.while_trips)
    return roof, coll


def model_flops_train(cfg, tokens: int) -> float:
    """6*N*D with N = active params (MoE-aware)."""
    return 6.0 * cfg.active_param_count() * tokens


def model_flops_decode(cfg, batch: int, context: int) -> float:
    """Per decode step: 2*N_active*B (GEMMs) + attention KV reads are
    memory-side; compute credit = 2*N_active*B."""
    return 2.0 * cfg.active_param_count() * batch


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out
