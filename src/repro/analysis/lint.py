"""gse-lint: static enforcement of the GSE integer parity contract.

The parity contract (docs/architecture.md, docs/static-analysis.md) is only
as strong as its weakest new code path: a fresh `jnp.exp2` scale, a raw
`os.environ` knob read, a Pallas kernel without an oracle, or a hand-rolled
word-plane dequant all reintroduce exactly the fusion-dependent bugs earlier
PRs eradicated. This module is an AST linter over ``src/`` with a rule
registry that turns those prose rules into a CI gate:

  R1 inexact-scale-math     no ``jnp.exp2`` / ``jnp.log2`` / ``2 ** e``
                            scale math outside the blessed exact-math
                            helpers (``core/gse.py``) and the numpy-domain
                            oracles (``kernels/ref.py``). Use ``exp2_int``
                            / ``ceil_log2`` — XLA's transcendentals are
                            fusion-dependent approximations.
  R2 raw-env-knob-read      every ``REPRO_*`` env knob is read through the
                            ``repro.kernels.ops`` tristate registry; raw
                            ``os.environ`` reads bypass the shared 1/0/auto
                            vocabulary (writes are fine — the dry-run
                            harness sets knobs for subprocesses).
  R3 kernel-missing-oracle  every Pallas kernel entry point (a top-level
                            function in ``kernels/`` that calls
                            ``pallas_call``) must have a registered oracle
                            in ``kernels/ref.py`` named ``<base>_ref`` or
                            ``<base>_oracle`` (base = the entry name minus
                            a trailing ``_pallas``).
  R4 hand-rolled-dequant    no raw shift/mask math on packed word planes
                            and no ``.astype`` dequant of
                            ``mantissa_words`` / ``exponent_words``
                            outside the shared pack/unpack bodies — one
                            definition per bit-math body, or the wire
                            format silently forks.
  R5 raw-plane-slice        plane-prefix views (docs/gse-format.md §7)
                            are taken only through
                            ``PackedGSETensor.with_bits`` /
                            ``plane_prefix_words`` — a hand-sliced
                            ``words[..., :b*chunks]`` elsewhere skips the
                            width validation and the exponent-shift
                            bookkeeping, silently decoding at the wrong
                            scale.

Pragmas: append ``# gse-lint: disable=R1`` (comma-separate several rule
ids) to a line to suppress findings on that line; a file-level
``# gse-lint: disable-file=R3`` comment anywhere in the file suppresses a
rule for the whole file.

Baseline: grandfathered violations live in ``tools/gse_lint_baseline.json``
as (rule, path, symbol, code) fingerprints — line-number free, so the
baseline survives unrelated edits and the report stays diff-friendly.
``--update-baseline`` rewrites it from the current findings; the exit code
only counts *non-baselined* findings.

CLI (also exposed as ``tools/gse_lint.py``)::

    python tools/gse_lint.py [paths...] [--json out.json]
                             [--baseline tools/gse_lint_baseline.json]
                             [--update-baseline]
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

RULE_IDS = ("R1", "R2", "R3", "R4", "R5")

_PRAGMA_RE = re.compile(r"#\s*gse-lint:\s*disable=([A-Za-z0-9,\s]+)")
_PRAGMA_FILE_RE = re.compile(r"#\s*gse-lint:\s*disable-file=([A-Za-z0-9,\s]+)")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    name: str               # short rule slug
    path: str               # posix relpath from the lint root
    line: int
    col: int
    message: str
    symbol: str             # enclosing def/class qualname ("" = module)
    code: str               # normalized source line

    @property
    def fingerprint(self) -> Tuple[str, str, str, str]:
        """Line-number-free identity used for baseline matching."""
        return (self.rule, self.path, self.symbol, self.code)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule}[{self.name}] {self.message}")


def _attr_chain(node: ast.AST) -> List[str]:
    """['os', 'environ', 'get'] for ``os.environ.get`` — [] if not a pure
    Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def _identifiers(node: ast.AST) -> Iterable[str]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub.id
        elif isinstance(sub, ast.Attribute):
            yield sub.attr


class _Rule:
    id = ""
    name = ""

    def applies(self, relpath: str) -> bool:
        return True

    def check(self, ctx: "_FileContext") -> Iterable[Finding]:
        raise NotImplementedError


@dataclasses.dataclass
class _FileContext:
    relpath: str
    tree: ast.Module
    lines: List[str]
    root: Path
    symbols: Dict[int, str]   # line -> enclosing qualname

    def finding(self, rule: "_Rule", node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        code = self.lines[line - 1].strip() if line <= len(self.lines) else ""
        return Finding(rule.id, rule.name, self.relpath, line,
                       getattr(node, "col_offset", 0) + 1, message,
                       self.symbols.get(line, ""), code)


def _symbol_map(tree: ast.Module) -> Dict[int, str]:
    """Map every source line to the qualname of its enclosing def/class."""
    out: Dict[int, str] = {}

    def visit(node: ast.AST, prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                qual = f"{prefix}.{child.name}" if prefix else child.name
                end = getattr(child, "end_lineno", child.lineno)
                for ln in range(child.lineno, end + 1):
                    out[ln] = qual
                visit(child, qual)
            else:
                visit(child, prefix)

    visit(tree, "")
    return out


# ---------------------------------------------------------------------------
# R1: inexact scale math
# ---------------------------------------------------------------------------

class RuleInexactScaleMath(_Rule):
    id = "R1"
    name = "inexact-scale-math"
    # the exact-math helper definitions and the numpy-domain oracles
    BLESSED = {"repro/core/gse.py", "repro/kernels/ref.py"}
    _FUNCS = {"exp2", "log2"}

    def applies(self, relpath: str) -> bool:
        return relpath not in self.BLESSED

    def check(self, ctx: _FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                if chain and chain[-1] in self._FUNCS:
                    yield ctx.finding(
                        self, node,
                        f"`{'.'.join(chain)}` is a fusion-dependent "
                        "approximation; use the exact-integer helpers "
                        "`exp2_int` / `ceil_log2` from repro.core.gse")
            elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.Pow):
                base = node.left
                if (isinstance(base, ast.Constant)
                        and base.value in (2, 2.0)
                        and not isinstance(node.right, ast.Constant)
                        and not _is_const_expr(node.right)):
                    yield ctx.finding(
                        self, node,
                        "`2 ** e` with a non-constant exponent: build "
                        "power-of-two scales with `exp2_int` (exact IEEE-754 "
                        "bit assembly)")


def _is_const_expr(node: ast.AST) -> bool:
    """Constant-folded exponents (``2 ** -20``, ``2 ** (8 - 1)``) are exact
    host math, not traced scale math."""
    return all(isinstance(n, (ast.Constant, ast.UnaryOp, ast.BinOp,
                              ast.unaryop, ast.operator))
               for n in ast.walk(node))


# ---------------------------------------------------------------------------
# R2: raw REPRO_* env reads
# ---------------------------------------------------------------------------

class RuleRawEnvRead(_Rule):
    id = "R2"
    name = "raw-env-knob-read"
    # the tristate registry itself is the single blessed reader
    BLESSED = {"repro/kernels/ops.py"}

    def applies(self, relpath: str) -> bool:
        return relpath not in self.BLESSED

    @staticmethod
    def _repro_key(node: Optional[ast.AST]) -> Optional[str]:
        if (isinstance(node, ast.Constant) and isinstance(node.value, str)
                and node.value.startswith("REPRO_")):
            return node.value
        return None

    def check(self, ctx: _FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            key = None
            if isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                if chain[-2:] == ["environ", "get"] or \
                        (len(chain) == 2 and chain[-1] == "getenv"):
                    key = self._repro_key(node.args[0] if node.args else None)
            elif isinstance(node, ast.Subscript) and \
                    isinstance(node.ctx, ast.Load):
                chain = _attr_chain(node.value)
                if chain and chain[-1] == "environ":
                    key = self._repro_key(node.slice)
            if key:
                yield ctx.finding(
                    self, node,
                    f"raw read of {key}: route it through the shared "
                    "1/0/auto registry (repro.kernels.ops._env_tristate / "
                    "ENV_TRISTATE_KNOBS) so stray values cannot be "
                    "silently truthy")


# ---------------------------------------------------------------------------
# R3: Pallas kernel entry points must have a registered oracle
# ---------------------------------------------------------------------------

class RuleKernelOracle(_Rule):
    id = "R3"
    name = "kernel-missing-oracle"
    EXEMPT = {"repro/kernels/ref.py", "repro/kernels/ops.py",
              "repro/kernels/__init__.py"}

    def __init__(self):
        self._oracles: Optional[Set[str]] = None

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("repro/kernels/") and \
            relpath not in self.EXEMPT

    def _oracle_names(self, root: Path) -> Set[str]:
        if self._oracles is None:
            ref = root / "repro" / "kernels" / "ref.py"
            self._oracles = set()
            if ref.exists():
                tree = ast.parse(ref.read_text(encoding="utf-8"))
                self._oracles = {n.name for n in tree.body
                                 if isinstance(n, ast.FunctionDef)}
        return self._oracles

    def check(self, ctx: _FileContext) -> Iterable[Finding]:
        oracles = self._oracle_names(ctx.root)
        for node in ctx.tree.body:
            if not isinstance(node, ast.FunctionDef):
                continue
            calls_pallas = any(
                isinstance(sub, ast.Call)
                and (_attr_chain(sub.func)[-1:] == ["pallas_call"])
                for sub in ast.walk(node))
            if not calls_pallas:
                continue
            base = node.name[:-len("_pallas")] \
                if node.name.endswith("_pallas") else node.name
            wanted = (f"{base}_ref", f"{base}_oracle")
            if not any(w in oracles for w in wanted):
                yield ctx.finding(
                    self, node,
                    f"Pallas kernel entry `{node.name}` has no registered "
                    f"oracle in kernels/ref.py (expected `{wanted[0]}` or "
                    f"`{wanted[1]}`) — every kernel is swept bit-exact "
                    "against a pure-jnp oracle")


# ---------------------------------------------------------------------------
# R4: hand-rolled word-plane dequant
# ---------------------------------------------------------------------------

class RuleHandRolledDequant(_Rule):
    id = "R4"
    name = "hand-rolled-dequant"
    # the shared pack/unpack bit-math bodies (one definition per body)
    BLESSED = {"repro/core/gse.py", "repro/kernels/gse_unpack.py",
               "repro/kernels/gse_quant_pack.py", "repro/kernels/ref.py"}
    _WORDY = re.compile(r"word|plane", re.IGNORECASE)
    _PACKED_ATTRS = {"mantissa_words", "exponent_words"}

    def applies(self, relpath: str) -> bool:
        return relpath not in self.BLESSED

    def check(self, ctx: _FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) and \
                    isinstance(node.op, (ast.LShift, ast.RShift)):
                wordy = [i for i in _identifiers(node)
                         if self._WORDY.search(i)]
                if wordy:
                    yield ctx.finding(
                        self, node,
                        f"raw shift on packed word data ({wordy[0]!r}): "
                        "unpack through gse_unpack / unpack_tile / "
                        "unpack_mantissas — one definition per bit-math "
                        "body, or the wire format silently forks")
            elif isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                if len(chain) >= 2 and chain[-1] == "astype" and \
                        any(a in self._PACKED_ATTRS for a in chain[:-1]):
                    yield ctx.finding(
                        self, node,
                        "`.astype` on a packed word plane is not a dequant "
                        "— word planes only become values through "
                        "gse_unpack / unpack_tile")
                elif isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "astype" and \
                        any(a in self._PACKED_ATTRS
                            for a in _identifiers(node.func.value)):
                    yield ctx.finding(
                        self, node,
                        "`.astype` on an expression over packed word planes "
                        "— word planes only become values through "
                        "gse_unpack / unpack_tile")


# ---------------------------------------------------------------------------
# R5: hand-sliced plane-prefix views
# ---------------------------------------------------------------------------

class RulePlanePrefixSlice(_Rule):
    id = "R5"
    name = "raw-plane-slice"
    # the one sanctioned slice body (plane_prefix_words / with_bits) and
    # the numpy-domain oracles that define the truncation semantics
    BLESSED = {"repro/core/gse.py", "repro/kernels/ref.py"}
    _WORDY = re.compile(r"(^|_)words?($|\b)|mantissa_words", re.IGNORECASE)
    _WIDTHY = re.compile(r"bits|chunk|plane", re.IGNORECASE)

    def applies(self, relpath: str) -> bool:
        return relpath not in self.BLESSED

    def _bounded_slices(self, node: ast.Subscript) -> Iterable[ast.Slice]:
        sl = node.slice
        elts = sl.elts if isinstance(sl, ast.Tuple) else [sl]
        for e in elts:
            if isinstance(e, ast.Slice) and e.upper is not None:
                yield e

    def check(self, ctx: _FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Subscript)
                    and isinstance(node.ctx, ast.Load)):
                continue
            if not any(self._WORDY.search(i)
                       for i in _identifiers(node.value)):
                continue
            for sl in self._bounded_slices(node):
                if any(self._WIDTHY.search(i)
                       for i in _identifiers(sl.upper)):
                    yield ctx.finding(
                        self, node,
                        "hand-sliced plane prefix on packed words: take "
                        "bit-width views only through "
                        "`PackedGSETensor.with_bits` / "
                        "`plane_prefix_words` (repro.core.gse) — a raw "
                        "slice skips width validation and the "
                        "exponent-shift bookkeeping (docs/gse-format.md "
                        "§7)")
                    break


def default_rules() -> List[_Rule]:
    return [RuleInexactScaleMath(), RuleRawEnvRead(), RuleKernelOracle(),
            RuleHandRolledDequant(), RulePlanePrefixSlice()]


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

def _pragmas(lines: List[str]) -> Tuple[Dict[int, Set[str]], Set[str]]:
    per_line: Dict[int, Set[str]] = {}
    per_file: Set[str] = set()
    for i, text in enumerate(lines, start=1):
        m = _PRAGMA_FILE_RE.search(text)
        if m:
            per_file |= {r.strip() for r in m.group(1).split(",") if r.strip()}
            continue
        m = _PRAGMA_RE.search(text)
        if m:
            per_line[i] = {r.strip() for r in m.group(1).split(",")
                           if r.strip()}
    return per_line, per_file


def lint_file(path: Path, root: Path,
              rules: Optional[List[_Rule]] = None) -> List[Finding]:
    relpath = path.resolve().relative_to(root.resolve()).as_posix()
    src = path.read_text(encoding="utf-8")
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding("E0", "syntax-error", relpath, e.lineno or 1, 1,
                        f"cannot parse: {e.msg}", "", "")]
    lines = src.splitlines()
    per_line, per_file = _pragmas(lines)
    ctx = _FileContext(relpath, tree, lines, root, _symbol_map(tree))
    out: List[Finding] = []
    for rule in (rules if rules is not None else default_rules()):
        if rule.id in per_file or not rule.applies(relpath):
            continue
        for f in rule.check(ctx):
            if rule.id in per_line.get(f.line, ()):
                continue
            out.append(f)
    return out


def iter_py_files(target: Path) -> Iterable[Path]:
    if target.is_file():
        yield target
        return
    for p in sorted(target.rglob("*.py")):
        if "__pycache__" not in p.parts:
            yield p


def lint_paths(paths: Iterable[Path], root: Path,
               rules: Optional[List[_Rule]] = None) -> List[Finding]:
    shared = rules if rules is not None else default_rules()
    out: List[Finding] = []
    for target in paths:
        for path in iter_py_files(Path(target)):
            out.extend(lint_file(path, root, shared))
    out.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return out


# ---------------------------------------------------------------------------
# Baseline
# ---------------------------------------------------------------------------

BASELINE_SCHEMA = "repro/gse_lint_baseline/v1"


def load_baseline(path: Path) -> Set[Tuple[str, str, str, str]]:
    if not path.exists():
        return set()
    data = json.loads(path.read_text(encoding="utf-8"))
    return {(v["rule"], v["path"], v.get("symbol", ""), v.get("code", ""))
            for v in data.get("violations", [])}


def write_baseline(path: Path, findings: List[Finding]) -> None:
    entries = sorted(
        {(f.rule, f.path, f.symbol, f.code) for f in findings})
    data = {
        "schema": BASELINE_SCHEMA,
        "violations": [
            {"rule": r, "path": p, "symbol": s, "code": c}
            for r, p, s, c in entries],
    }
    path.write_text(json.dumps(data, indent=2) + "\n", encoding="utf-8")


def split_baselined(findings: List[Finding],
                    baseline: Set[Tuple[str, str, str, str]]
                    ) -> Tuple[List[Finding], List[Finding]]:
    fresh = [f for f in findings if f.fingerprint not in baseline]
    grandfathered = [f for f in findings if f.fingerprint in baseline]
    return fresh, grandfathered


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

REPORT_SCHEMA = "repro/gse_lint_report/v1"


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    default_root = Path(__file__).resolve().parents[2]        # .../src
    parser = argparse.ArgumentParser(
        prog="gse-lint", description=__doc__.splitlines()[0])
    parser.add_argument("paths", nargs="*", type=Path,
                        help="files/dirs to lint (default: the src tree)")
    parser.add_argument("--root", type=Path, default=default_root,
                        help="lint root for relpaths / rule blessing")
    parser.add_argument("--baseline", type=Path,
                        default=default_root.parent / "tools"
                        / "gse_lint_baseline.json")
    parser.add_argument("--update-baseline", action="store_true",
                        help="rewrite the baseline from current findings")
    parser.add_argument("--json", type=Path, default=None,
                        help="write a machine-readable report here")
    args = parser.parse_args(argv)

    paths = args.paths or [args.root]
    findings = lint_paths(paths, args.root)
    if args.update_baseline:
        write_baseline(args.baseline, findings)
        print(f"gse-lint: baseline updated with {len(findings)} finding(s) "
              f"-> {args.baseline}")
        return 0
    baseline = load_baseline(args.baseline)
    fresh, grandfathered = split_baselined(findings, baseline)

    if args.json:
        report = {
            "schema": REPORT_SCHEMA,
            "root": str(args.root),
            "fresh": [f.to_dict() for f in fresh],
            "baselined": [f.to_dict() for f in grandfathered],
            "ok": not fresh,
        }
        args.json.write_text(json.dumps(report, indent=2) + "\n",
                             encoding="utf-8")

    for f in fresh:
        print(f.render())
    if grandfathered:
        print(f"gse-lint: {len(grandfathered)} baselined finding(s) "
              "suppressed")
    if fresh:
        print(f"gse-lint: {len(fresh)} violation(s)")
        return 1
    print("gse-lint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
