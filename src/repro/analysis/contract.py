"""Trace-level audit of the integer parity contract (Layer 2 of the gate).

The source linter (:mod:`repro.analysis.lint`) catches contract violations
you can see in the text; this module catches the ones you can only see in
the lowered program. It builds the representative jitted programs the
contract talks about — the packed backward GEMMs and score tile with
``int_mac``, the packed decode attention step on the kernel route, the QCD
train step with ``residuals_packed``, the packed gradient all-gather — and
asserts structural invariants on the optimized HLO / jaxpr:

  int-dot-route      audited int-MAC programs contain **zero** fp dots
                     (score/backward GEMMs are s8xs8->s32 `dot`s); the
                     attention program may keep exactly the PV GEMMs in
                     fp32, identified by result minor dim == head_dim.
  one-tile-unpacked  no materialized fp32 buffer matches the full unpacked
                     shape (or flat size) of any packed operand — "peak
                     live unpacked = one tile". Fusion bodies are excluded
                     (fusion internals are VMEM under XLA's fusion model);
                     while-loop bodies are not (their buffers materialize).
  u32-wire           gradient collectives carry packed u32 word payloads:
                     every `all_gather` moves unsigned words, no collective
                     moves floats, and no transcendental scale math
                     (exp/exp2/log/log2/pow) appears anywhere in the
                     compressed-mean program.
  guard-coverage     every Pallas kernel entry that accepts ``int_mac``
                     reaches a `check_int_mac_depth` call (bounded tier) or
                     the `gse_score_tile` exact-tier recipe, and the
                     exact-tier closure `group * qmax^2 < 2^24` holds for
                     the widest supported mantissa.
  view-zero-copy     the ``kv_active_bits`` / per-sequence ``kv_trunc``
                     serve programs (plane-prefix views,
                     docs/gse-format.md §7) never materialize the cache:
                     no fp buffer of the unpacked KV shape at any width
                     (a dequant→requantize view) and no cache-shaped u32
                     word buffer produced by arithmetic (an eager
                     truncate-and-re-pack — the view must stay a prefix
                     read of the stored planes).

The invariant engines (:func:`dot_census`, :func:`fp_buffer_scan`) are
pure functions of HLO text so tests can feed them deliberately broken
programs; the check_* functions lower real programs and apply them.

CLI (the CI gate)::

    PYTHONPATH=src python -m repro.analysis.contract --check \
        --json contract_report.json
"""
from __future__ import annotations

import ast
import json
import os
import sys
from contextlib import contextmanager
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.hlo_walk import (_CALL_SINGLE_RE, _shape_list,
                                     parse_hlo)

REPORT_SCHEMA = "repro/contract_audit/v1"

_FP_DTYPES = {"f16", "bf16", "f32", "f64"}


# ---------------------------------------------------------------------------
# Invariant engines: pure functions of HLO text
# ---------------------------------------------------------------------------

def dot_census(hlo_text: str) -> Dict[str, List[dict]]:
    """Classify every `dot` in the module as integer or floating point.

    A dot is *integer* iff its result and both operands are integer-typed;
    anything touching f16/bf16/f32/f64 counts as fp. Returns
    ``{"int": [...], "fp": [...]}`` with one record per dot:
    computation, result dtype/dims, operand dtypes, and the HLO line.
    """
    out: Dict[str, List[dict]] = {"int": [], "fp": []}
    for comp in parse_hlo(hlo_text).values():
        for ins in comp.instrs:
            if ins.opcode != "dot":
                continue
            res = _shape_list(ins.result)
            if not res:
                continue
            r_dt, r_dims = res[0]
            op_dts: List[str] = []
            for name in ins.operands():
                shp = comp.defs.get(name)
                if shp:
                    op_dts.extend(dt for dt, _ in _shape_list(shp))
            kind = ("fp" if r_dt in _FP_DTYPES
                    or any(dt in _FP_DTYPES for dt in op_dts) else "int")
            out[kind].append({
                "computation": comp.name, "result_dtype": r_dt,
                "result_dims": r_dims, "operand_dtypes": op_dts,
                "line": ins.line[:200],
            })
    return out


def _fusion_bodies(hlo_text: str) -> Set[str]:
    """Names of computations called (only) from `fusion` instructions —
    their buffers are VMEM-resident under XLA's fusion model and must not
    count as materialized. While/conditional bodies stay in the scan."""
    fused: Set[str] = set()
    for comp in parse_hlo(hlo_text).values():
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                fused.update(_CALL_SINGLE_RE.findall(ins.line))
    return fused


def fp_buffer_scan(hlo_text: str, dims: Sequence[Sequence[int]] = (),
                   flat_sizes: Iterable[int] = ()) -> List[dict]:
    """Find materialized fp buffers matching a forbidden unpacked shape.

    Flags every instruction result outside fusion bodies whose dtype is
    floating point and whose dims exactly match an entry of ``dims`` or
    whose element count is in ``flat_sizes``. These are the "someone
    dequantized the whole packed tensor" signatures.
    """
    want_dims = {tuple(d) for d in dims}
    want_flat = set(flat_sizes)
    fused = _fusion_bodies(hlo_text)
    hits: List[dict] = []
    for comp in parse_hlo(hlo_text).values():
        if comp.name in fused:
            continue
        for ins in comp.instrs:
            for dt, rdims in _shape_list(ins.result):
                if dt not in _FP_DTYPES:
                    continue
                n = 1
                for d in rdims:
                    n *= d
                if tuple(rdims) in want_dims or n in want_flat:
                    hits.append({"computation": comp.name, "dtype": dt,
                                 "dims": rdims, "line": ins.line[:200]})
    return hits


def audit_int_route(hlo_text: str,
                    fp_ok_minor_dim: Optional[int] = None) -> List[str]:
    """Violation strings for the int-dot-route invariant.

    ``fp_ok_minor_dim``: if set, fp dots whose result minor dimension
    equals it are tolerated (the attention PV GEMM contracts over the
    softmax axis in fp32 by design — its result minor dim is head_dim).
    """
    census = dot_census(hlo_text)
    out = []
    if not census["int"]:
        out.append("no integer dot found on an int-MAC route")
    for d in census["fp"]:
        if fp_ok_minor_dim is not None and d["result_dims"] \
                and d["result_dims"][-1] == fp_ok_minor_dim:
            continue
        out.append(f"fp dot on int-MAC route: {d['line']}")
    return out


def audit_no_unpacked_fp(hlo_text: str, dims: Sequence[Sequence[int]],
                         flat_sizes: Iterable[int]) -> List[str]:
    return [f"materialized fp buffer of full unpacked shape: "
            f"{h['dtype']}{h['dims']} in {h['computation']}: {h['line']}"
            for h in fp_buffer_scan(hlo_text, dims, flat_sizes)]


# word-producing arithmetic: the opcodes a truncate-and-re-pack (shift,
# mask, or-together) would lower to. slice/reshape/copy/bitcast — the
# legitimate zero-copy prefix ops — are deliberately absent.
_U32_COMPUTE_OPS = {"add", "subtract", "multiply", "divide", "and", "or",
                    "xor", "not", "shift-left", "shift-right-logical",
                    "shift-right-arithmetic", "select", "convert", "clamp"}


def u32_word_compute_scan(hlo_text: str,
                          dims: Sequence[Sequence[int]]) -> List[dict]:
    """Find cache-shaped u32 word buffers produced by *arithmetic*.

    The plane-prefix view contract: a narrowed read is a prefix slice of
    the stored planes — never a recomputed word stream. Tile-local unpack
    arithmetic is fine (tile shapes, and fusion bodies are VMEM); an
    instruction outside fusion bodies whose u32 result matches a full
    word-cache shape in ``dims`` AND whose opcode is word-producing
    arithmetic is an eager whole-cache re-pack.
    """
    want = {tuple(d) for d in dims}
    fused = _fusion_bodies(hlo_text)
    hits: List[dict] = []
    for comp in parse_hlo(hlo_text).values():
        if comp.name in fused:
            continue
        for ins in comp.instrs:
            if ins.opcode not in _U32_COMPUTE_OPS:
                continue
            for dt, rdims in _shape_list(ins.result):
                if dt == "u32" and tuple(rdims) in want:
                    hits.append({"computation": comp.name, "dims": rdims,
                                 "line": ins.line[:200]})
    return hits


def audit_view_zero_copy(hlo_text: str,
                         word_dims: Sequence[Sequence[int]]) -> List[str]:
    return [f"cache-shaped u32 words produced by arithmetic (re-pack, "
            f"not a prefix view): u32{h['dims']} in {h['computation']}: "
            f"{h['line']}"
            for h in u32_word_compute_scan(hlo_text, word_dims)]


# ---------------------------------------------------------------------------
# jaxpr engine (collectives + transcendental scale math)
# ---------------------------------------------------------------------------

_COLLECTIVES = {"all_gather", "psum", "pmax", "pmin", "ppermute",
                "all_to_all", "reduce_scatter"}
_TRANSCENDENTAL = {"exp", "exp2", "log", "log2", "pow"}


def jaxpr_census(jaxpr) -> Dict[str, List[List[Tuple[tuple, str]]]]:
    """Recursively collect every primitive with its invar (shape, dtype)
    pairs, descending into nested jaxprs (shard_map/scan/cond bodies)."""
    from jax._src.core import ClosedJaxpr, Jaxpr
    prims: Dict[str, List[List[Tuple[tuple, str]]]] = {}

    def walk(jx):
        for eqn in jx.eqns:
            prims.setdefault(eqn.primitive.name, []).append(
                [(tuple(v.aval.shape), str(v.aval.dtype))
                 for v in eqn.invars if hasattr(v, "aval")])
            for p in eqn.params.values():
                for q in (p if isinstance(p, (list, tuple)) else [p]):
                    if isinstance(q, ClosedJaxpr):
                        walk(q.jaxpr)
                    elif isinstance(q, Jaxpr):
                        walk(q)

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return prims


def audit_wire(prims: Dict[str, List[List[Tuple[tuple, str]]]]) -> List[str]:
    """Violations of the u32-wire invariant on a jaxpr census."""
    out = []
    gathers = prims.get("all_gather", [])
    if not gathers:
        out.append("no all_gather found in the compressed-mean program")
    for invars in gathers:
        for shape, dtype in invars:
            if not dtype.startswith("uint"):
                out.append(f"all_gather payload is {dtype}{list(shape)}, "
                           "not packed unsigned words")
    for name in _COLLECTIVES:
        for invars in prims.get(name, []):
            for shape, dtype in invars:
                if dtype.startswith(("float", "bfloat")):
                    out.append(f"float collective {name}: "
                               f"{dtype}{list(shape)}")
    for name in sorted(_TRANSCENDENTAL & set(prims)):
        out.append(f"transcendental scale math in wire program: "
                   f"`{name}` x{len(prims[name])} — use ceil_log2/exp2_int")
    return out


# ---------------------------------------------------------------------------
# Representative programs
# ---------------------------------------------------------------------------

@contextmanager
def _env(**kw):
    old = {k: os.environ.get(k) for k in kw}
    for k, v in kw.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v
    try:
        yield
    finally:
        for k, v in old.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _optimized_hlo(fn, *args) -> str:
    import jax
    return jax.jit(fn).lower(*args).compile().as_text()


def lower_matmul_nt(bits: int = 8, m: int = 16, n: int = 64,
                    k: int = 96, group: int = 32) -> str:
    """Packed dX backward GEMM (nt) on the realigned int32 MAC route."""
    import jax
    from repro.core.gse import gse_pack, gse_quantize, unpack_exponents
    from repro.kernels import ops
    a = jax.random.normal(jax.random.PRNGKey(0), (m, n))
    b = jax.random.normal(jax.random.PRNGKey(1), (n, k))
    ap = gse_pack(gse_quantize(a, bits, group))
    bp = gse_pack(gse_quantize(b, bits, group))
    ae = unpack_exponents(ap.exponent_words, ap.exponent_shape)
    be = unpack_exponents(bp.exponent_words, bp.exponent_shape)
    return _optimized_hlo(
        lambda aw, ae, bw, be: ops.gse_matmul_packed_nt(
            aw, ae, bw, be, bits, bits, group, group, int_mac=True),
        ap.mantissa_words, ae, bp.mantissa_words, be)


def lower_matmul_tn(bits: int = 8, m: int = 32, n: int = 64,
                    k: int = 96, group: int = 32) -> str:
    """Packed dW backward GEMM (tn): contraction over the shared leading
    axis — operands (N, M) and (N, K), both grouped along their last dim
    (so m must be group-divisible here, unlike the nt case)."""
    import jax
    from repro.core.gse import gse_pack, gse_quantize, unpack_exponents
    from repro.kernels import ops
    a = jax.random.normal(jax.random.PRNGKey(0), (n, m))
    b = jax.random.normal(jax.random.PRNGKey(1), (n, k))
    ap = gse_pack(gse_quantize(a, bits, group))
    bp = gse_pack(gse_quantize(b, bits, group))
    ae = unpack_exponents(ap.exponent_words, ap.exponent_shape)
    be = unpack_exponents(bp.exponent_words, bp.exponent_shape)
    return _optimized_hlo(
        lambda aw, ae, bw, be: ops.gse_matmul_packed_tn(
            aw, ae, bw, be, bits, bits, group, group, int_mac=True),
        ap.mantissa_words, ae, bp.mantissa_words, be)


def lower_score_tile(r: int = 8, s: int = 64, d: int = 64,
                     bits: int = 8, group: int = 32) -> str:
    """Exact-tier attention score tile on already-int8 mantissas."""
    import jax
    from repro.kernels import ops
    from repro.kernels.gse_matmul import gse_score_tile
    q = jax.random.normal(jax.random.PRNGKey(0), (r, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (s, d))
    qm, qe = ops.gse_quantize(q, bits, group)
    km, ke = ops.gse_quantize(k, bits, group)
    return _optimized_hlo(
        lambda a, b, c, e: gse_score_tile(a, b, c, e, group=group),
        qm, qe, km, ke)


# packed decode attention program geometry (kernel route, GQA, interpret
# mode on CPU): head_dim 32 so the tolerated fp PV GEMM (result minor dim
# == D) can never be confused with a score GEMM (minor dim == bk=64).
_ATTN = dict(b=1, t=8, h=4, kv=2, d=32, s=128, bq=8, bk=64, bits=8)


def lower_attention(int_mac: bool = True) -> str:
    """Packed decode attention step on the forced kernel route."""
    import jax
    from repro.kernels import ops
    p = _ATTN
    q = jax.random.normal(jax.random.PRNGKey(0), (p["b"], p["t"], p["h"],
                                                  p["d"]))
    k = jax.random.normal(jax.random.PRNGKey(1), (p["b"], p["s"], p["kv"],
                                                  p["d"]))
    v = jax.random.normal(jax.random.PRNGKey(2), (p["b"], p["s"], p["kv"],
                                                  p["d"]))
    kw, ke = ops.quant_pack_kv_rows(k, p["bits"])
    vw, ve = ops.quant_pack_kv_rows(v, p["bits"])
    with _env(REPRO_FAP_ROUTE="kernel", REPRO_INT_MAC=None):
        return _optimized_hlo(
            lambda q, kw, ke, vw, ve: ops.flash_attention_packed(
                q, kw, ke, vw, ve, causal=False,
                q_offset=p["s"] - p["t"], bq=p["bq"], bk=p["bk"],
                int_mac=int_mac),
            q, kw, ke, vw, ve)


# paged decode attention geometry: pool of `pages` physical pages of
# `page` rows; two sequences, ragged offsets. page == bk so the paged
# kernel and the jnp fallback tile identically.
_PAGED = dict(b=2, t=8, h=4, kv=2, d=32, page=64, maxp=2, bits=8)


def lower_paged_attention(int_mac: bool = True) -> str:
    """Paged packed decode attention on the forced kernel route."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    p = _PAGED
    n_pages = 2 + p["b"] * p["maxp"]          # null + trash + allocated
    s = p["maxp"] * p["page"]
    q = jax.random.normal(jax.random.PRNGKey(0), (p["b"], p["t"], p["h"],
                                                  p["d"]))
    k = jax.random.normal(jax.random.PRNGKey(1), (p["b"], s, p["kv"],
                                                  p["d"]))
    v = jax.random.normal(jax.random.PRNGKey(2), (p["b"], s, p["kv"],
                                                  p["d"]))
    kw, ke = ops.quant_pack_kv_rows(k, p["bits"])
    vw, ve = ops.quant_pack_kv_rows(v, p["bits"])

    def pool(x):                               # rows -> per-page pool
        xp = x.reshape(p["b"] * p["maxp"], p["page"], *x.shape[2:])
        return jnp.concatenate([jnp.zeros_like(xp[:2]), xp], axis=0)

    pt = jnp.arange(2, n_pages, dtype=jnp.int32).reshape(p["b"], p["maxp"])
    off = jnp.asarray([s - p["t"], s - p["t"] - 16], jnp.int32)
    with _env(REPRO_FAP_ROUTE="kernel", REPRO_INT_MAC=None):
        return _optimized_hlo(
            lambda q, kw, ke, vw, ve, pt, off: ops.flash_attention_paged(
                q, kw, ke, vw, ve, pt, causal=False, q_offset=off,
                int_mac=int_mac),
            q, pool(kw), pool(ke), pool(vw), pool(ve), pt, off)


def lower_view_attention(active_bits: int = 4) -> str:
    """Planar packed decode attention reading the ``kv_active_bits`` plane
    prefix of an 8-bit cache (the with_bits serve program, kernel route)."""
    import jax
    from repro.kernels import ops
    p = _ATTN
    q = jax.random.normal(jax.random.PRNGKey(0), (p["b"], p["t"], p["h"],
                                                  p["d"]))
    k = jax.random.normal(jax.random.PRNGKey(1), (p["b"], p["s"], p["kv"],
                                                  p["d"]))
    v = jax.random.normal(jax.random.PRNGKey(2), (p["b"], p["s"], p["kv"],
                                                  p["d"]))
    kw, ke = ops.quant_pack_kv_rows(k, p["bits"])
    vw, ve = ops.quant_pack_kv_rows(v, p["bits"])
    with _env(REPRO_FAP_ROUTE="kernel", REPRO_INT_MAC=None):
        return _optimized_hlo(
            lambda q, kw, ke, vw, ve: ops.flash_attention_packed(
                q, kw, ke, vw, ve, causal=False,
                q_offset=p["s"] - p["t"], bq=p["bq"], bk=p["bk"],
                kv_active_bits=active_bits),
            q, kw, ke, vw, ve)


def lower_mixed_paged_attention() -> str:
    """Paged decode attention with a traced per-sequence ``kv_trunc``
    vector — the mixed-``kv_bits`` continuous-batching decode program."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    p = _PAGED
    n_pages = 2 + p["b"] * p["maxp"]
    s = p["maxp"] * p["page"]
    q = jax.random.normal(jax.random.PRNGKey(0), (p["b"], p["t"], p["h"],
                                                  p["d"]))
    k = jax.random.normal(jax.random.PRNGKey(1), (p["b"], s, p["kv"],
                                                  p["d"]))
    v = jax.random.normal(jax.random.PRNGKey(2), (p["b"], s, p["kv"],
                                                  p["d"]))
    kw, ke = ops.quant_pack_kv_rows(k, p["bits"])
    vw, ve = ops.quant_pack_kv_rows(v, p["bits"])

    def pool(x):
        xp = x.reshape(p["b"] * p["maxp"], p["page"], *x.shape[2:])
        return jnp.concatenate([jnp.zeros_like(xp[:2]), xp], axis=0)

    pt = jnp.arange(2, n_pages, dtype=jnp.int32).reshape(p["b"], p["maxp"])
    off = jnp.asarray([s - p["t"], s - p["t"] - 16], jnp.int32)
    tr = jnp.asarray([0, 5], jnp.int32)       # lane widths 8 and 3
    with _env(REPRO_FAP_ROUTE="kernel", REPRO_INT_MAC=None):
        return _optimized_hlo(
            lambda q, kw, ke, vw, ve, pt, off, tr: ops.flash_attention_paged(
                q, kw, ke, vw, ve, pt, causal=False, q_offset=off,
                kv_trunc=tr),
            q, pool(kw), pool(ke), pool(vw), pool(ve), pt, off, tr)


def trace_wire_jaxpr(n: int = 256, bits: int = 8, group: int = 32,
                     packed: bool = True):
    """jaxpr of the shard_mapped packed gradient mean on a 1-device mesh."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compressed_mean
    from repro.distributed.sharding import shard_map_compat
    mesh = jax.make_mesh((1,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (1, n)) * 1e-3
    r0 = jnp.zeros((1, n))

    def f(gg, rr):
        return compressed_mean(gg[0], rr[0], "pod", bits=bits, group=group,
                               packed=packed)

    fm = shard_map_compat(f, mesh, in_specs=(P("pod"), P("pod")),
                          out_specs=(P(), P()))
    return jax.make_jaxpr(fm)(g, r0)


# ---------------------------------------------------------------------------
# Checks
# ---------------------------------------------------------------------------

def check_backward_gemms() -> dict:
    """int-dot-route + one-tile-unpacked on the nt/tn int_mac GEMMs."""
    violations: List[str] = []
    # geometries chosen so the fp32 GEMM *output* shape (m, k) collides
    # with neither operand's unpacked shape (n, m) / (n, k) / (m, n)
    geoms = (("nt", lower_matmul_nt, (16, 64, 96)),
             ("tn", lower_matmul_tn, (32, 64, 96)))
    for bits in (4, 8):
        for tag, lower, (m, n, k) in geoms:
            hlo = lower(bits=bits, m=m, n=n, k=k)
            shapes = [(m, n), (n, k), (n, m)]
            flat = {m * n, n * k}
            violations += [f"[{tag} b{bits}] {v}"
                           for v in audit_int_route(hlo)]
            violations += [f"[{tag} b{bits}] {v}"
                           for v in audit_no_unpacked_fp(hlo, shapes, flat)]
    return _result("backward-gemms-int-route", violations,
                   "nt/tn packed GEMMs, bits 4 and 8, int_mac=True: only "
                   "integer dots, no operand-sized fp buffer")


def check_score_tile() -> dict:
    hlo = lower_score_tile()
    return _result("score-tile-int-route", audit_int_route(hlo),
                   "exact-tier score tile: the one GEMM is s8xs8->s32")


def check_attention() -> dict:
    p = _ATTN
    hlo = lower_attention(int_mac=True)
    violations = audit_int_route(hlo, fp_ok_minor_dim=p["d"])
    cache_dims = [(p["b"], p["s"], p["kv"], p["d"]),
                  (p["b"] * p["kv"], p["s"], p["d"])]
    cache_flat = {p["b"] * p["s"] * p["kv"] * p["d"]}
    violations += audit_no_unpacked_fp(hlo, cache_dims, cache_flat)
    return _result("attention-int-route", violations,
                   "packed decode attention (kernel route, int_mac): score "
                   "dots integer, fp only in the PV GEMM, no fp buffer of "
                   "full KV-cache shape")


def check_paged_attention() -> dict:
    p = _PAGED
    hlo = lower_paged_attention(int_mac=True)
    violations = audit_int_route(hlo, fp_ok_minor_dim=p["d"])
    s = p["maxp"] * p["page"]
    n_pages = 2 + p["b"] * p["maxp"]
    # forbid both the full gathered-KV fp buffer (someone dequantized a
    # sequence's whole page walk) and the full pool-sized fp buffer
    # (someone dequantized the pool itself)
    dims = [(p["b"], s, p["kv"], p["d"]),
            (p["b"] * p["kv"], s, p["d"]),
            (n_pages, p["page"], p["kv"], p["d"])]
    flat = {p["b"] * s * p["kv"] * p["d"],
            n_pages * p["page"] * p["kv"] * p["d"]}
    violations += audit_no_unpacked_fp(hlo, dims, flat)
    return _result("paged-attention-int-route", violations,
                   "paged packed decode attention (kernel route, int_mac, "
                   "per-sequence offsets): score dots integer, fp only in "
                   "the PV GEMM, no fp buffer of gathered-KV or pool shape")


def check_train_residuals() -> dict:
    """QCD train step with residuals_packed: the saved-for-backward set is
    packed u32 word streams, never a full-precision activation residual."""
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp
    from repro.core.policy import QuantPolicy
    from repro.models import model as M
    from repro.models.config import ModelConfig
    from repro.train.step import lm_loss

    cfg = ModelConfig(name="audit", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=256, vocab=64,
                      vocab_pad_multiple=32, remat=True)
    pol = _dc.replace(QuantPolicy.gsq(8, rank=8), residuals_packed=True)
    fz, tr = M.init_model(jax.random.PRNGKey(0), cfg, pol)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 4, 64)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1),
             "loss_mask": jnp.ones((4, 32), jnp.float32)}
    _, vjp = jax.vjp(lambda t: lm_loss(t, fz, batch, cfg, pol)[0], tr)
    leaves = jax.tree_util.tree_leaves(vjp)

    violations = []
    words = [l for l in leaves if l.dtype == jnp.uint32]
    if not words:
        violations.append("no packed u32 residual words saved for backward")
    elif not any(l.ndim >= 2 and l.shape[0] == cfg.n_layers for l in words):
        violations.append("no per-layer stacked (L, ...) word stream "
                          "among the residuals")
    res_size = 4 * 32 * cfg.d_ff          # smallest per-GEMM residual
    for l in leaves:
        if jnp.issubdtype(l.dtype, jnp.floating) and l.size >= res_size:
            violations.append(f"full-precision residual leaf "
                              f"{l.dtype}{tuple(l.shape)} saved for "
                              "backward")
    return _result("train-residuals-packed", violations,
                   "QCD train step (residuals_packed): saved-for-backward "
                   "set is packed u32 word streams only")


def check_collective_wire() -> dict:
    prims = jaxpr_census(trace_wire_jaxpr(packed=True))
    return _result("gradient-wire-u32", audit_wire(prims),
                   "packed compressed_mean: all_gather carries u32 words, "
                   "no float collectives, no transcendental scale math")


def check_guard_coverage() -> dict:
    """Every int_mac Pallas entry reaches a depth guard or the exact tier,
    and the exact-tier closure bound holds."""
    from repro.core.gse import DEFAULT_GROUP, qmax_for_bits
    from repro.kernels.gse_matmul import int_mac_max_depth

    violations: List[str] = []
    qmax = qmax_for_bits(8)
    if DEFAULT_GROUP * qmax * qmax >= 2 ** 24:
        violations.append(
            f"exact-tier closure broken: group({DEFAULT_GROUP}) * "
            f"qmax({qmax})^2 >= 2^24 — group MACs no longer fp32-exact")
    if int_mac_max_depth(8, 8) < 64:
        violations.append("bounded-tier depth limit below the default "
                          "64-wide K tile")

    kern_dir = Path(__file__).resolve().parents[1] / "kernels"
    audited = 0
    for path in sorted(kern_dir.glob("*.py")):
        tree = ast.parse(path.read_text(encoding="utf-8"))
        fns = {n.name: n for n in tree.body
               if isinstance(n, ast.FunctionDef)}

        def names_in(fn) -> Set[str]:
            out: Set[str] = set()
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Name):
                    out.add(sub.id)
                elif isinstance(sub, ast.Attribute):
                    out.add(sub.attr)
            return out

        reach: Dict[str, Set[str]] = {nm: names_in(fn)
                                      for nm, fn in fns.items()}
        for nm, fn in fns.items():
            args = fn.args
            takes_int_mac = any(
                a.arg == "int_mac"
                for a in args.args + args.kwonlyargs + args.posonlyargs)
            if not takes_int_mac:
                continue
            # transitive closure through same-module top-level functions
            seen: Set[str] = set()
            frontier = {nm}
            while frontier:
                cur = frontier.pop()
                seen.add(cur)
                frontier |= (reach.get(cur, set()) & set(fns)) - seen
            names: Set[str] = set()
            for s in seen:
                names |= reach.get(s, set())
            if "pallas_call" not in names:
                continue
            audited += 1
            if not names & {"check_int_mac_depth", "gse_score_tile"}:
                violations.append(
                    f"{path.name}:{fn.lineno} `{nm}` takes int_mac and "
                    "lowers a Pallas kernel but never reaches "
                    "check_int_mac_depth or the gse_score_tile exact tier")
    if audited == 0:
        violations.append("no int_mac Pallas entry points found — the "
                          "guard-coverage scan is miswired")
    return _result("int-mac-guard-coverage", violations,
                   f"{audited} int_mac Pallas entry(ies) all reach a depth "
                   "guard or the exact tier; closure bound holds")


def check_plane_prefix_view() -> dict:
    """view-zero-copy: the with_bits / mixed-kv_trunc serve programs hold
    both the no-unpacked-fp and the no-re-pack invariant."""
    p = _ATTN
    chunks = p["d"] // 32
    violations: List[str] = []

    hlo = lower_view_attention(active_bits=4)
    cache_dims = [(p["b"], p["s"], p["kv"], p["d"]),
                  (p["b"] * p["kv"], p["s"], p["d"])]
    cache_flat = {p["b"] * p["s"] * p["kv"] * p["d"]}
    violations += [f"[planar b=4] {v}" for v in
                   audit_no_unpacked_fp(hlo, cache_dims, cache_flat)]
    # cache-shaped word streams at the narrowed and the stored width, in
    # the row layout and the folded/plane-axis layouts the wrapper builds
    word_dims = []
    for wb in (4, 8):
        word_dims += [(p["b"], p["s"], p["kv"], wb * chunks),
                      (p["b"], p["kv"], p["s"], wb * chunks),
                      (p["b"] * p["kv"], p["s"], wb * chunks),
                      (p["b"] * p["kv"], p["s"], wb, chunks)]
    violations += [f"[planar b=4] {v}" for v in
                   audit_view_zero_copy(hlo, word_dims)]

    pp = _PAGED
    s = pp["maxp"] * pp["page"]
    n_pages = 2 + pp["b"] * pp["maxp"]
    pchunks = pp["d"] // 32
    hlo = lower_mixed_paged_attention()
    dims = [(pp["b"], s, pp["kv"], pp["d"]),
            (pp["b"] * pp["kv"], s, pp["d"]),
            (n_pages, pp["page"], pp["kv"], pp["d"])]
    flat = {pp["b"] * s * pp["kv"] * pp["d"],
            n_pages * pp["page"] * pp["kv"] * pp["d"]}
    violations += [f"[paged mixed-trunc] {v}" for v in
                   audit_no_unpacked_fp(hlo, dims, flat)]
    pool_words = []
    for wb in (4, 8):
        pool_words += [(n_pages, pp["page"], pp["kv"], wb * pchunks),
                       (n_pages, pp["page"], pp["kv"], wb, pchunks)]
    violations += [f"[paged mixed-trunc] {v}" for v in
                   audit_view_zero_copy(hlo, pool_words)]
    return _result("plane-prefix-view-zero-copy", violations,
                   "with_bits (planar b=4/8) and mixed-kv_trunc paged serve "
                   "programs: no fp buffer of unpacked KV shape, no "
                   "cache-shaped u32 words from arithmetic (prefix read, "
                   "not re-pack)")


def _result(name: str, violations: List[str], detail: str) -> dict:
    return {"name": name, "ok": not violations, "detail": detail,
            "violations": violations}


ALL_CHECKS = (check_backward_gemms, check_score_tile, check_attention,
              check_paged_attention, check_train_residuals,
              check_collective_wire, check_guard_coverage,
              check_plane_prefix_view)


def run_checks(checks=ALL_CHECKS) -> dict:
    results = []
    for chk in checks:
        try:
            results.append(chk())
        except Exception as e:            # a crashed check is a failure
            results.append(_result(chk.__name__, [f"check crashed: {e!r}"],
                                   chk.__doc__ or ""))
    return {"schema": REPORT_SCHEMA,
            "ok": all(r["ok"] for r in results),
            "checks": results}


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    parser = argparse.ArgumentParser(
        prog="repro.analysis.contract",
        description="trace-level integer parity contract audit")
    parser.add_argument("--check", action="store_true",
                        help="run the full audit (exit 1 on violation)")
    parser.add_argument("--json", type=Path, default=None,
                        help="write the machine-readable report here")
    args = parser.parse_args(argv)
    if not args.check:
        parser.print_help()
        return 2
    report = run_checks()
    if args.json:
        args.json.write_text(json.dumps(report, indent=2) + "\n",
                             encoding="utf-8")
    for r in report["checks"]:
        status = "ok  " if r["ok"] else "FAIL"
        print(f"[{status}] {r['name']}: {r['detail']}")
        for v in r["violations"]:
            print(f"       - {v}")
    print("contract audit:", "PASS" if report["ok"] else "FAIL")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
