"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — under a
scan-over-layers + grad-accum + flash-attention-scan architecture that
undercounts FLOPs/bytes/collectives by orders of magnitude. This walker
parses the optimized (post-SPMD, per-device) HLO text, builds the
computation call graph and an SSA def->shape map, extracts while-loop trip
counts from their condition computations, and accumulates:

  * flops               — 2 * prod(result_dims) * contraction for every dot
  * hbm_bytes           — operand+result bytes of top-level instructions
                          (fusion internals excluded: they live in
                          VMEM/registers under XLA's fusion model)
  * collective_bytes    — payload bytes per collective kind
  * int8_dot_flops      — dot FLOPs whose lhs operand is s8/u8 (MXU int8)

all multiplied through nested while trip counts.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_CALL_SINGLE_RE = re.compile(
    r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_CALL_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _shape_bytes(text: str) -> int:
    tot = 0
    for dt, dims in _shape_list(text):
        n = 1
        for d in dims:
            n *= d
        tot += n * _DTYPE_BYTES[dt]
    return tot


@dataclasses.dataclass
class Instr:
    name: str
    result: str          # result shape text
    opcode: str
    line: str            # full line (metadata stripped)

    def operands(self) -> List[str]:
        """SSA names referenced inside opcode(...)."""
        body = self.line.split(self.opcode + "(", 1)
        if len(body) < 2:
            return []
        args = body[1]
        # cut at the matching close paren (first '), ' attr separator or EOL)
        depth = 1
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = args[:i]
                    break
        return _OPERAND_RE.findall(args)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    defs: Dict[str, str]        # ssa name -> result shape text


def _parse_header_params(header: str, defs: Dict[str, str]):
    """header like '%name (p0: s32[], p1: (f32[2,3]{1,0}, s8[4]))' —
    register p0/p1 shapes."""
    m = re.match(r"^(?:ENTRY\s+)?%?[\w.\-]+\s*\((.*)\)\s*->", header)
    if not m:
        return
    params = m.group(1)
    # split top-level commas
    depth = 0
    parts, cur = [], []
    for ch in params:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    for p in parts:
        if ":" not in p:
            continue
        name, shape = p.split(":", 1)
        defs[name.strip().lstrip("%")] = shape.strip()


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not line.startswith("  ") and s.endswith("{") and "->" in s:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", s)
            if m:
                cur = Computation(m.group(1), [], {})
                _parse_header_params(s, cur.defs)
                comps[cur.name] = cur
                continue
        if s == "}" and not line.startswith("   "):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            clean = s.split(", metadata=")[0]
            ins = Instr(mi.group(1), mi.group(2), mi.group(3), clean)
            cur.instrs.append(ins)
            cur.defs[ins.name] = ins.result
    return comps


def _operand_bytes(ins: Instr, comp: Computation) -> int:
    tot = 0
    for name in ins.operands():
        shp = comp.defs.get(name)
        if shp:
            tot += _shape_bytes(shp)
    return tot


def _dot_flops(ins: Instr, comp: Computation) -> Tuple[float, bool]:
    shapes = _shape_list(ins.result)
    if not shapes:
        return 0.0, False
    _, rdims = shapes[0]
    n_res = 1
    for d in rdims:
        n_res *= d
    ops = ins.operands()
    lhs_shape = comp.defs.get(ops[0], "") if ops else ""
    lhs_shapes = _shape_list(lhs_shape)
    contraction = 1
    is_int8 = False
    if lhs_shapes:
        lhs_dt, lhs_dims = lhs_shapes[0]
        is_int8 = lhs_dt in ("s8", "u8", "s4", "u4")
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
        if mc:
            for idx in [int(i) for i in mc.group(1).split(",") if i]:
                if idx < len(lhs_dims):
                    contraction *= lhs_dims[idx]
    return 2.0 * n_res * contraction, is_int8


def _conv_flops(ins: Instr, comp: Computation) -> float:
    shapes = _shape_list(ins.result)
    if not shapes:
        return 0.0
    _, rdims = shapes[0]
    n_res = 1
    for d in rdims:
        n_res *= d
    ops = ins.operands()
    if len(ops) < 2:
        return 0.0
    kshape = _shape_list(comp.defs.get(ops[1], ""))
    if not kshape:
        return 0.0
    _, kdims = kshape[0]
    k = 1
    for d in kdims:
        k *= d
    if rdims:
        k = max(k // max(rdims[-1], 1), 1)
    return 2.0 * n_res * k


def _trip_count(cond: Computation) -> int:
    best = None
    for ins in cond.instrs:
        for m in _TRIP_RE.finditer(ins.line):
            v = int(m.group(1))
            best = v if best is None else max(best, v)
    return best if best and best > 0 else 1


@dataclasses.dataclass
class WalkResult:
    flops: float = 0.0
    int8_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    while_trips: List[int] = dataclasses.field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "iota", "partition-id",
                   "replica-id"}

_CALLER_OPS = {"fusion", "call", "custom-call", "reduce", "sort", "scatter",
               "select-and-scatter", "map", "reduce-window", "all-reduce",
               "reduce-scatter"}


def _accumulate(dst: WalkResult, src: WalkResult, times: float):
    dst.flops += src.flops * times
    dst.int8_flops += src.int8_flops * times
    dst.hbm_bytes += src.hbm_bytes * times
    for k in dst.collective_bytes:
        dst.collective_bytes[k] += src.collective_bytes.get(k, 0.0) * times
        dst.collective_counts[k] += src.collective_counts.get(k, 0.0) * times
    dst.while_trips.extend(src.while_trips)


def walk(text: str) -> WalkResult:
    comps = parse_hlo(text)
    memo: Dict[str, WalkResult] = {}

    def instr_bytes(ins: Instr, comp: Computation) -> float:
        return _shape_bytes(ins.result) + _operand_bytes(ins, comp)

    def comp_cost(name: str) -> WalkResult:
        if name in memo:
            return memo[name]
        out = WalkResult()
        memo[name] = out
        comp = comps.get(name)
        if comp is None:
            return out
        for ins in comp.instrs:
            op = ins.opcode
            base_coll = None
            for c in _COLLECTIVES:
                if op == c or op.startswith(c + "-"):
                    base_coll = c
                    break
            if op == "dot":
                f, i8 = _dot_flops(ins, comp)
                out.flops += f
                if i8:
                    out.int8_flops += f
                out.hbm_bytes += instr_bytes(ins, comp)
            elif op == "convolution":
                out.flops += _conv_flops(ins, comp)
                out.hbm_bytes += instr_bytes(ins, comp)
            elif op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mcnd = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trips = 1
                if mcnd and mcnd.group(1) in comps:
                    trips = _trip_count(comps[mcnd.group(1)])
                out.while_trips.append(trips)
                if mb:
                    _accumulate(out, comp_cost(mb.group(1)), trips)
            elif base_coll is not None:
                nbytes = _shape_bytes(ins.result)
                if base_coll == "reduce-scatter":
                    ob = _operand_bytes(ins, comp)
                    nbytes = ob or nbytes
                out.collective_bytes[base_coll] += nbytes
                out.collective_counts[base_coll] += 1
                out.hbm_bytes += instr_bytes(ins, comp)
            elif op == "conditional":
                subs = [comp_cost(s) for s in _called_comps(ins)]
                if subs:
                    worst = max(subs, key=lambda s: s.flops + s.hbm_bytes)
                    _accumulate(out, worst, 1)
                out.hbm_bytes += instr_bytes(ins, comp)
            elif op in _CALLER_OPS:
                for sub_name in _called_comps(ins):
                    sub = comp_cost(sub_name)
                    out.flops += sub.flops
                    out.int8_flops += sub.int8_flops
                out.hbm_bytes += instr_bytes(ins, comp)
            elif op not in _SKIP_BYTES_OPS:
                out.hbm_bytes += instr_bytes(ins, comp)
        return out

    def _called_comps(ins: Instr) -> List[str]:
        out = [m.group(1) for m in _CALL_SINGLE_RE.finditer(ins.line)]
        for m in _CALL_BRANCH_RE.finditer(ins.line):
            for nm in m.group(1).split(","):
                nm = nm.strip().lstrip("%")
                if nm:
                    out.append(nm)
        return out

    called = set()
    for comp in comps.values():
        for ins in comp.instrs:
            called.update(_called_comps(ins))
    entries = [n for n in comps if n not in called]
    total = WalkResult()
    for e in entries:
        _accumulate(total, comp_cost(e), 1)
    return total


def top_contributors(text: str, k: int = 15):
    """Per-instruction (bytes, flops) x trip-multiplier attribution — the
    §Perf profiling view. Returns two lists of dicts sorted desc."""
    comps = parse_hlo(text)
    by_bytes: Dict[str, float] = {}
    by_flops: Dict[str, float] = {}

    def _called(ins):
        out = [m.group(1) for m in _CALL_SINGLE_RE.finditer(ins.line)]
        for m in _CALL_BRANCH_RE.finditer(ins.line):
            out += [x.strip().lstrip("%") for x in m.group(1).split(",") if x]
        return out

    def visit(name: str, mult: float, depth: int):
        comp = comps.get(name)
        if comp is None or depth > 12:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mcnd = re.search(r"condition=%?([\w.\-]+)", ins.line)
                trips = _trip_count(comps[mcnd.group(1)]) \
                    if mcnd and mcnd.group(1) in comps else 1
                if mb:
                    visit(mb.group(1), mult * trips, depth + 1)
                continue
            key = (f"{name}/{ins.name}:{op} {ins.result[:48]}")
            if op == "dot":
                f, _ = _dot_flops(ins, comp)
                by_flops[key] = by_flops.get(key, 0.0) + f * mult
            if op in _CALLER_OPS:
                for sub in _called(ins):
                    sc = comps.get(sub)
                    if sc:
                        for si in sc.instrs:
                            if si.opcode == "dot":
                                f, _ = _dot_flops(si, sc)
                                kk = f"{sub}/{si.name}:dot(fused)"
                                by_flops[kk] = by_flops.get(kk, 0) + f * mult
            if op not in _SKIP_BYTES_OPS:
                b = _shape_bytes(ins.result) + _operand_bytes(ins, comp)
                by_bytes[key] = by_bytes.get(key, 0.0) + b * mult

    called = set()
    for comp in comps.values():
        for ins in comp.instrs:
            called.update(_called(ins))
    for e in [n for n in comps if n not in called]:
        visit(e, 1.0, 0)
    top_b = sorted(by_bytes.items(), key=lambda x: -x[1])[:k]
    top_f = sorted(by_flops.items(), key=lambda x: -x[1])[:k]
    return ([{"instr": a, "bytes": b} for a, b in top_b],
            [{"instr": a, "flops": f} for a, f in top_f])
