"""Paged packed-KV: page allocator semantics, pool seeding (offset-binary
packed zeros), prefill page scatter, and bit-parity of the paged attention
kernel against the gather+jnp fallback, the numpy oracle, and the
non-paged planar kernel at equal content."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.kernels import ops, ref
from repro.kernels.flash_attention_packed import (
    dequant_kv_rows, flash_attention_paged_pallas, gather_pages,
    quant_pack_kv_rows)
from repro.serve import paging


# ---------------- allocator ------------------------------------------------

def test_alloc_free_reuse_fifo():
    a = paging.PageAllocator(n_pages=8, page_size=4)
    assert a.n_allocatable == 6 and a.n_free == 6
    s1 = a.alloc(2)
    s2 = a.alloc(3)
    assert s1 == [2, 3] and s2 == [4, 5, 6]
    assert a.utilization() == pytest.approx(5 / 6)
    a.free(s1)
    # FIFO: the freed pages come back *after* the still-virgin page 7
    assert a.alloc(3) == [7, 2, 3]
    assert a.n_free == 0


def test_alloc_is_all_or_nothing_and_exhaustion_backpressures():
    a = paging.PageAllocator(n_pages=6, page_size=4)
    assert a.alloc(3) == [2, 3, 4]
    # 1 page free, 2 requested: None and *no partial reservation leaked*
    assert a.alloc(2) is None
    assert a.n_free == 1
    assert a.alloc(1) == [5]


def test_fragmented_free_list_still_serves_full_spans():
    """Pages are position-independent (the page table provides ordering),
    so a fragmented free list serves any span that fits."""
    a = paging.PageAllocator(n_pages=10, page_size=4)
    spans = [a.alloc(2) for _ in range(4)]          # pages 2..9
    a.free(spans[0])                                 # holes at 2,3
    a.free(spans[2])                                 # holes at 6,7
    got = a.alloc(4)
    assert sorted(got) == [2, 3, 6, 7]


def test_double_free_and_foreign_page_raise():
    a = paging.PageAllocator(n_pages=6, page_size=4)
    s = a.alloc(2)
    a.free(s)
    with pytest.raises(ValueError):
        a.free(s)
    with pytest.raises(ValueError):
        a.free([paging.NULL_PAGE])                   # reserved, never owned


def test_pages_for_rounds_up():
    a = paging.PageAllocator(n_pages=6, page_size=8)
    assert a.pages_for(1) == 1
    assert a.pages_for(8) == 1
    assert a.pages_for(9) == 2


# ---------------- pool seeding / scatter -----------------------------------

def test_packed_zero_rows_dequantize_to_exact_zero():
    """Offset-binary fields: the zero pattern is NOT all-zero words (those
    dequantize to -qmax); the seeded pattern hits exactly 0.0."""
    cfg = reduced_config("granite_3_2b")
    zw, ze = paging.packed_zero_rows(cfg, bits=8)
    assert bool(jnp.any(zw != 0))
    d = cfg.resolved_head_dim
    deq = dequant_kv_rows(zw[None, None], ze[None, None], d)
    np.testing.assert_array_equal(np.asarray(deq), 0.0)


def test_init_paged_cache_layout_and_seeding():
    cfg = reduced_config("granite_3_2b")
    cache = paging.init_paged_cache(cfg, batch=3, n_pages=6, page_size=4,
                                    max_pages=2, bits=8)
    l, kv = cfg.n_layers, cfg.n_kv_heads
    assert cache["kp_words"].shape[:4] == (l, 6, 4, kv)
    assert cache["pages"].shape == (l, 3, 2)
    # every slot starts inactive: whole table on the trash page
    assert np.all(np.asarray(cache["pages"]) == paging.TRASH_PAGE)
    assert cache["index"].shape == (l, 3)
    # every page of every pool dequantizes to exact zeros
    d = cfg.resolved_head_dim
    deq = dequant_kv_rows(cache["vp_words"][0], cache["vp_exp"][0], d)
    np.testing.assert_array_equal(np.asarray(deq), 0.0)


def test_slot_and_trash_rows():
    row = paging.slot_page_row([5, 2, 9], 5)
    np.testing.assert_array_equal(
        row, [5, 2, 9, paging.NULL_PAGE, paging.NULL_PAGE])
    np.testing.assert_array_equal(paging.trash_page_row(3),
                                  [paging.TRASH_PAGE] * 3)


def test_scatter_prefill_pages_roundtrip():
    """Scattered pages gather back to exactly the planar rows (full-page
    overwrite: no residue of the pool's previous contents)."""
    cfg = reduced_config("granite_3_2b")
    l, kv, d = cfg.n_layers, cfg.n_kv_heads, cfg.resolved_head_dim
    page, n = 4, 2
    cache = paging.init_paged_cache(cfg, batch=1, n_pages=6, page_size=page,
                                    max_pages=n, bits=8)
    x = jax.random.normal(jax.random.PRNGKey(0), (l, 1, n * page, kv, d))
    w, e = quant_pack_kv_rows(x, 8)
    planar = {"k_words": w, "k_exp": e, "v_words": w, "v_exp": e}
    out = paging.scatter_prefill_pages(cache, planar, [4, 2])
    # layer 0: gather over the page walk reproduces the planar words
    got = gather_pages(out["kp_words"][0], jnp.asarray([[4, 2]], jnp.int32))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(w[0, 0]))


def test_page_pool_pspec_resolves():
    from repro.distributed.sharding import ShardingRules
    mesh = jax.make_mesh((1,), ("data",))
    spec = paging.page_pool_pspec(mesh, ShardingRules.single_pod(),
                                  kv_heads=2, n_pages=8)
    assert len(spec) <= 5                    # a valid 5-dim PartitionSpec


# ---------------- paged attention parity -----------------------------------

def _paged_setup(seed, b, s, kv, d, page, bits):
    """Contiguous planar K/V planes + the same rows scattered to pools
    under one shared permuted page table. Returns
    (kw, ke, vw, ve, kpw, kpe, vpw, vpe, pt)."""
    maxp = s // page
    n_pages = paging.FIRST_PAGE + b * maxp
    k = jax.random.normal(jax.random.PRNGKey(seed), (b, s, kv, d)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(seed + 100),
                          (b, s, kv, d)) * 0.5
    kw, ke = quant_pack_kv_rows(k, bits)
    vw, ve = quant_pack_kv_rows(v, bits)
    rng = np.random.default_rng(seed)
    pt = rng.permutation(np.arange(paging.FIRST_PAGE, n_pages)).reshape(
        b, maxp).astype(np.int32)

    def pool(x):
        p = np.zeros((n_pages, page) + x.shape[2:], np.asarray(x).dtype)
        xn = np.asarray(x).reshape(b, maxp, page, *x.shape[2:])
        for i in range(b):
            for j in range(maxp):
                p[pt[i, j]] = xn[i, j]
        return jnp.asarray(p)
    return (kw, ke, vw, ve, pool(kw), pool(ke), pool(vw), pool(ve),
            jnp.asarray(pt))


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
def test_paged_kernel_bit_exact_vs_fallback_and_planar(bits, causal, window):
    """The paged Pallas kernel (page-table SMEM prefetch + per-sequence
    offset vector) is bit-identical to (a) the gather+jnp fallback and
    (b) the non-paged planar kernel fed the same rows contiguously at
    bk == page — paging must not change one bit of the output."""
    b, t, h, kv, d, s, page = 2, 8, 4, 2, 32, 128, 64
    kw, ke, vw, ve, kpw, kpe, vpw, vpe, pt = _paged_setup(
        1 + bits, b, s, kv, d, page, bits)
    q = jax.random.normal(jax.random.PRNGKey(3), (b, t, h, d))
    off = jnp.asarray([s - t, s - t - 16], jnp.int32)   # ragged offsets

    def run(route):
        import os
        os.environ["REPRO_FAP_ROUTE"] = route
        try:
            return ops.flash_attention_paged(
                q, kpw, kpe, vpw, vpe, pt, causal=causal, window=window,
                q_offset=off)
        finally:
            del os.environ["REPRO_FAP_ROUTE"]

    ok = run("kernel")
    assert ops.last_paged_route()[0] == "kernel"
    oj = run("fallback")
    assert ops.last_paged_route()[0] == "fallback"
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(oj))
    # same content through the non-paged planar kernel, same tiling
    op = ops.flash_attention_packed(q, kw, ke, vw, ve, causal=causal,
                                    window=window, q_offset=off, bk=page)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(op))


def test_paged_kernel_bit_exact_vs_oracle():
    b, t, h, kv, d, s, page = 2, 8, 4, 2, 32, 128, 64
    _, _, _, _, kpw, kpe, vpw, vpe, pt = _paged_setup(10, b, s, kv, d,
                                                      page, 8)
    q = jax.random.normal(jax.random.PRNGKey(12), (b, t, h, d))
    off = np.asarray([s - t, s - t - 8])
    ok = flash_attention_paged_pallas(
        q.reshape(b, t, kv, h // kv, d).transpose(0, 2, 3, 1, 4).reshape(
            b * kv, h // kv, t, d),
        kpw, kpe, vpw, vpe, pt, q_offset=jnp.repeat(jnp.asarray(
            off, jnp.int32), kv), causal=True, bq=t)
    ok = ok.reshape(b, kv, h // kv, t, d).transpose(0, 3, 1, 2, 4).reshape(
        b, t, h, d)
    oo = ref.flash_attention_paged_oracle(q, kpw, kpe, vpw, vpe,
                                          np.asarray(pt), causal=True,
                                          q_offset=off, bq=t)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(oo))


def test_paged_tails_and_int_mac_parity():
    """fp tail rows (quantize-after-attend append) + int8 MXU score path
    on the paged kernel, bit-exact vs the gather fallback."""
    b, t, h, kv, d, s, page = 2, 4, 4, 2, 32, 128, 64
    _, _, _, _, kpw, kpe, vpw, vpe, pt = _paged_setup(20, b, s, kv, d,
                                                      page, 8)
    q = jax.random.normal(jax.random.PRNGKey(22), (b, t, h, d))
    kt = jax.random.normal(jax.random.PRNGKey(23), (b, t, kv, d))
    vt = jax.random.normal(jax.random.PRNGKey(24), (b, t, kv, d))
    off = jnp.asarray([s - t, s - t - 8], jnp.int32)

    def run(route):
        import os
        os.environ["REPRO_FAP_ROUTE"] = route
        try:
            return ops.flash_attention_paged(
                q, kpw, kpe, vpw, vpe, pt, causal=False, q_offset=off,
                k_tail=kt, v_tail=vt, int_mac=True)
        finally:
            del os.environ["REPRO_FAP_ROUTE"]

    np.testing.assert_array_equal(np.asarray(run("kernel")),
                                  np.asarray(run("fallback")))


def test_paged_null_page_columns_are_masked_noops():
    """A sequence whose page walk ends in null pages (allocated span
    shorter than max_pages) attends identically to the same rows under a
    full-span table — the quantized-zero columns sit behind the length
    mask."""
    b, t, h, kv, d, s, page = 1, 4, 2, 2, 32, 128, 64
    _, _, _, _, kpw, kpe, vpw, vpe, pt = _paged_setup(30, b, s, kv, d,
                                                      page, 8)
    q = jax.random.normal(jax.random.PRNGKey(32), (b, t, h, d))
    # live in the first page only; second logical page -> NULL_PAGE
    off = jnp.asarray([page - t], jnp.int32)
    pt_null = jnp.asarray([[int(pt[0, 0]), paging.NULL_PAGE]], jnp.int32)
    o_null = flash_attention_paged_pallas(
        q.transpose(0, 2, 1, 3).reshape(b * kv, h // kv, t, d),
        kpw, kpe, vpw, vpe, pt_null,
        q_offset=jnp.repeat(off, kv), causal=True, bq=t)
    o_full = flash_attention_paged_pallas(
        q.transpose(0, 2, 1, 3).reshape(b * kv, h // kv, t, d),
        kpw, kpe, vpw, vpe, pt[:1],
        q_offset=jnp.repeat(off, kv), causal=True, bq=t)
    np.testing.assert_array_equal(np.asarray(o_null), np.asarray(o_full))
