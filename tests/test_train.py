"""Training integration: loss decreases, grad accumulation invariance,
runner checkpoint-resume determinism, straggler watchdog."""
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import QuantPolicy
from repro.data.pipeline import DataConfig
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.adamw8bit import AdamW8bit
from repro.train.runner import RunnerConfig, TrainingRunner
from repro.train.step import (TrainConfig, accumulate_grads, lm_loss,
                              make_train_step, clip_by_global_norm)

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=64,
                  vocab_pad_multiple=32)
POL = QuantPolicy.gsq(8, rank=8)


def _mk(seed=0):
    fz, tr = M.init_model(jax.random.PRNGKey(seed), CFG, POL)
    return fz, tr


def _batch(b=8, t=64, seed=1):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (b, t), 4, 64)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1),
            "loss_mask": jnp.ones((b, t), jnp.float32)}


def test_accum_invariance():
    """accum=1 and accum=4 produce (nearly) the same mean gradient."""
    fz, tr = _mk()
    batch = _batch(b=8)
    _, _, g1 = accumulate_grads(tr, fz, batch, CFG, POL, 1)
    _, _, g4 = accumulate_grads(tr, fz, batch, CFG, POL, 4)
    dots, norms = 0.0, 1.0
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        a = a.astype(jnp.float32).ravel()
        b = b.astype(jnp.float32).ravel()
        na, nb = float(jnp.linalg.norm(a)), float(jnp.linalg.norm(b))
        if na > 1e-9 and nb > 1e-9:
            cos = float(jnp.dot(a, b)) / (na * nb)
            assert cos > 0.995, cos


def test_accum_tokens_metric_matches_single_shot():
    """The scan path accumulates the token count across microbatches so
    metrics match the accum_steps<=1 path (it used to drop the key)."""
    fz, tr = _mk()
    batch = _batch(b=8)
    _, aux1, _ = accumulate_grads(tr, fz, batch, CFG, POL, 1)
    _, aux4, _ = accumulate_grads(tr, fz, batch, CFG, POL, 4)
    assert set(aux4) == set(aux1)
    assert float(aux4["tokens"]) == pytest.approx(float(aux1["tokens"]))
    assert float(aux4["tokens"]) == 8 * 64


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                 rel=1e-3)


def test_loss_decreases_on_learnable_task(tmp_path):
    dcfg = DataConfig(vocab=64, seq_len=64, global_batch=8,
                      task_mix=("copy",))
    fz, tr = _mk()
    runner = TrainingRunner(
        CFG, POL, dcfg, AdamW8bit(lr=5e-3, warmup_steps=5),
        TrainConfig(accum_steps=1),
        RunnerConfig(total_steps=40, checkpoint_every=1000,
                     checkpoint_dir=str(tmp_path)),
        frozen=fz, train=tr)
    hist = runner.run()
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.1, (first, last)


def test_runner_resume_matches_uninterrupted(tmp_path):
    """Train 10 steps, checkpoint@5 — resuming 5..10 reproduces the same
    final loss (step-exact data + state restore)."""
    dcfg = DataConfig(vocab=64, seq_len=64, global_batch=4)

    def make(dirname, total):
        fz, tr = _mk(seed=3)
        return TrainingRunner(
            CFG, POL, dcfg, AdamW8bit(lr=1e-3),
            TrainConfig(accum_steps=1),
            RunnerConfig(total_steps=total, checkpoint_every=5,
                         checkpoint_dir=dirname),
            frozen=fz, train=tr, donate=False)

    d1 = str(tmp_path / "a")
    r1 = make(d1, 10)
    h1 = r1.run()

    d2 = str(tmp_path / "b")
    r2 = make(d2, 5)
    r2.run()                             # stops at 5 with a checkpoint
    r3 = make(d2, 10)
    assert r3.maybe_resume() and r3.step == 5
    h3 = r3.run()
    assert h1[-1]["loss"] == pytest.approx(h3[-1]["loss"], rel=1e-5)


def test_straggler_watchdog_detects():
    fz, tr = _mk()
    runner = TrainingRunner(
        CFG, POL, DataConfig(vocab=64, seq_len=32, global_batch=2),
        AdamW8bit(), TrainConfig(),
        RunnerConfig(total_steps=1, checkpoint_dir="/tmp/_w",
                     straggler_factor=2.0),
        frozen=fz, train=tr)
    runner._ewma = 0.01
    runner.step = 10
    runner._watchdog(0.5)
    assert runner.straggler_events and \
        runner.straggler_events[0]["dt"] == 0.5
