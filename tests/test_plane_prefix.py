"""Progressive precision: plane-prefix views of the packed substrate
(docs/gse-format.md §7).

Covers the truncation semantics end to end: ``with_bits(b)`` is a
zero-copy word slice that decodes to the floor-truncation oracle
bit-exactly (property-swept over widths and ragged K), composes, and is
the identity at the stored width; every packed kernel route
(unpack / fused matmul / nt / tn / planar attention / paged attention)
reads the same view through ``active_bits`` — incl. the int32-shift
fallback and the traced per-sequence ``kv_trunc`` vector; and
checkpoint ``restore(bits=b)`` loads the view without the wide stream.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.gse import (gse_pack, gse_quantize, gse_unpack,
                            plane_prefix_words)
from repro.kernels import ops, ref
from repro.kernels.flash_attention_packed import quant_pack_kv_rows
from repro.serve import paging


def _pack(seed, shape, bits=8, scale=0.5, group=32):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * scale
    return gse_pack(gse_quantize(x, bits, group))


def _assert_view_is_floor_trunc(p, b):
    """gse_unpack(with_bits(b)) == the numpy floor-division oracle, and
    the view's words are literally the stored prefix (zero-copy)."""
    stored = p.stored_bits
    if p.shape[-1] % 32 == 0:       # word-aligned: per-row chunks
        chunks = p.shape[-1] // 32
    else:                           # ragged: flat stream over all values
        chunks = -(-int(np.prod(p.shape)) // 32)
    v = p.with_bits(b)
    assert v.bits == b and v.stored_bits == stored
    assert v.exp_shift == stored - b
    assert v.mantissa_words.shape[-1] == b * chunks
    np.testing.assert_array_equal(
        np.asarray(v.mantissa_words),
        np.asarray(p.mantissa_words[..., :b * chunks]))
    assert v.exponent_words is p.exponent_words      # shared, not copied
    full = gse_unpack(p)
    got = gse_unpack(v)
    m_ref, e_ref = ref.plane_prefix_truncate_ref(
        np.asarray(full.mantissa), np.asarray(full.exponent), stored, b)
    np.testing.assert_array_equal(
        np.asarray(got.mantissa).astype(np.int32), m_ref)
    np.testing.assert_array_equal(
        np.asarray(got.exponent).astype(np.int32), e_ref)


# ---------------- core view semantics --------------------------------------

@pytest.mark.parametrize("b", range(2, 9))
@pytest.mark.parametrize("shape,group", [((4, 192), 32), ((3, 48), 16)])
def test_with_bits_matches_floor_trunc_oracle(b, shape, group):
    """Every prefix width of an 8-bit stream — word-aligned K and a
    ragged final chunk (K % 32 != 0)."""
    _assert_view_is_floor_trunc(_pack(b + shape[-1], shape, group=group), b)


def test_with_bits_identity_composition_and_bounds():
    p = _pack(7, (4, 64))
    assert p.with_bits(8) is p                       # stored width: no-op
    v = p.with_bits(6).with_bits(4)
    w = p.with_bits(4)
    assert v.bits == w.bits == 4 and v.exp_shift == w.exp_shift == 4
    np.testing.assert_array_equal(np.asarray(v.mantissa_words),
                                  np.asarray(w.mantissa_words))
    for bad in (1, 9):
        with pytest.raises(ValueError):
            p.with_bits(bad)
    with pytest.raises(ValueError):
        w.with_bits(6)                               # can't widen a view


@settings(max_examples=20, deadline=None)
@given(b1=st.integers(2, 8), b2=st.integers(2, 8),
       group=st.sampled_from([16, 32]), ngroups=st.integers(1, 8),
       seed=st.integers(0, 2 ** 16))
def test_property_prefix_view_is_floor_truncation(b1, b2, group, ngroups,
                                                  seed):
    """Any (stored, view) width pair, K swept across group counts incl.
    ragged word chunks (K % 32 != 0), decodes to floor truncation under
    the shared (now compensated) exponents."""
    stored, b = max(b1, b2), min(b1, b2)
    p = _pack(seed, (3, group * ngroups), bits=stored, group=group)
    _assert_view_is_floor_trunc(p, b)


def test_view_dequant_tracks_requantize_ordering():
    """The two tiers are distinct and ordered: the zero-copy view is
    lossier than a fresh b-bit re-quantization, both exact at b=8."""
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 256))
    p = gse_pack(gse_quantize(x, 8, 32))
    np.testing.assert_array_equal(
        np.asarray(p.with_bits(8).dequantize()),
        np.asarray(p.requantize(8).dequantize()))
    for b in (4, 6):
        ev = float(jnp.mean((p.with_bits(b).dequantize() - x) ** 2))
        er = float(jnp.mean((p.requantize(b).dequantize() - x) ** 2))
        assert er <= ev                     # nearest-even beats floor
        assert ev < float(jnp.mean(x ** 2))  # but the view is still signal


# ---------------- kernel routes: active_bits == the view --------------------

@pytest.mark.parametrize("b", [2, 5, 8])
@pytest.mark.parametrize("int32_shifts", [False, True])
def test_unpack_kernel_active_bits_vs_ref(b, int32_shifts):
    """The unpack kernel's narrowed index map (first b planes per tile)
    matches the ref oracle, incl. the bitcast-int32 shift mode."""
    from repro.kernels.gse_unpack import gse_unpack_pallas
    p = _pack(21 + b, (16, 64))
    y1 = gse_unpack_pallas(p.mantissa_words, 8, active_bits=b, bm=8,
                           bk=32, int32_shifts=int32_shifts)
    y2 = ref.gse_unpack_ref(p.mantissa_words, 8, b)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


@pytest.mark.parametrize("b", [2, 5, 8])
def test_matmul_packed_active_bits_vs_ref_and_face_width(b):
    """The fused packed matmul reading a b-bit prefix of 8-bit words
    equals (a) the ref oracle and (b) computing over the sliced face-width
    stream with the exponent shift folded in — the wrapper's contract."""
    from repro.kernels.gse_matmul import gse_matmul_packed_pallas
    ta = gse_quantize(
        jax.random.normal(jax.random.PRNGKey(31), (16, 64)) * 0.3, 8, 32)
    tb = gse_quantize(
        jax.random.normal(jax.random.PRNGKey(32), (32, 64)) * 0.3, 8, 32)
    pb = gse_pack(tb)
    kw = dict(bm=16, bn=32, bk=64)
    y1 = gse_matmul_packed_pallas(ta.mantissa, ta.exponent,
                                  pb.mantissa_words, tb.exponent, 8, 32,
                                  active_bits=b, **kw)
    y2 = ref.gse_matmul_packed_ref(ta.mantissa, ta.exponent,
                                   pb.mantissa_words, tb.exponent, 8, 32,
                                   active_bits=b)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    face = pb.with_bits(b)
    e_face = (tb.exponent.astype(jnp.int32) + face.exp_shift).astype(
        jnp.int8)
    y3 = gse_matmul_packed_pallas(ta.mantissa, ta.exponent,
                                  face.mantissa_words, e_face, b, 32, **kw)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y3))


@pytest.mark.parametrize("widths", [(4, 8), (8, 3), (5, 6)])
def test_backward_matmuls_active_bits_vs_ref(widths):
    """nt (dX-shaped) and tn (dW-shaped) packed matmuls narrow each
    operand independently, bit-exact vs the oracles at matching tiling."""
    from repro.kernels.gse_matmul import (gse_matmul_packed_nt_pallas,
                                          gse_matmul_packed_tn_pallas)
    aab, bab = widths
    aw = _pack(41, (32, 128))
    bw = _pack(42, (128, 64))
    y1 = gse_matmul_packed_nt_pallas(
        aw.mantissa_words, _exps(aw), bw.mantissa_words, _exps(bw), 8, 8,
        32, 32, bm=32, bn=64, bk=64, a_active_bits=aab, b_active_bits=bab)
    y2 = ref.gse_matmul_packed_nt_ref(
        aw.mantissa_words, _exps(aw), bw.mantissa_words, _exps(bw), 8, 8,
        32, bn=64, a_active_bits=aab, b_active_bits=bab)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    xw = _pack(43, (128, 64))
    dw = _pack(44, (128, 96))
    y1 = gse_matmul_packed_tn_pallas(
        xw.mantissa_words, _exps(xw), dw.mantissa_words, _exps(dw), 8, 8,
        32, 32, bm=64, bn=32, bk=32, a_active_bits=aab, b_active_bits=bab)
    y2 = ref.gse_matmul_packed_tn_ref(
        xw.mantissa_words, _exps(xw), dw.mantissa_words, _exps(dw), 8, 8,
        32, bm=64, a_active_bits=aab, b_active_bits=bab)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def _exps(p):
    from repro.core.gse import unpack_exponents
    return unpack_exponents(p.exponent_words, p.exponent_shape)


# ---------------- attention: static width and traced kv_trunc ---------------

def _routed(route, fn, *a, **kw):
    os.environ["REPRO_FAP_ROUTE"] = route
    try:
        return fn(*a, **kw)
    finally:
        del os.environ["REPRO_FAP_ROUTE"]


@pytest.mark.parametrize("b", [3, 6])
def test_attention_kv_active_bits_routes_vs_face_width(b):
    """Planar attention with ``kv_active_bits=b`` over the 8-bit cache:
    kernel and fallback routes agree, and both equal attending over the
    literally-sliced b-bit stream with compensated exponents — a narrowed
    read IS the b-bit cache."""
    bs, t, h, kv, d, s, bk = 2, 8, 4, 2, 32, 128, 64
    q = jax.random.normal(jax.random.PRNGKey(51), (bs, t, h, d))
    k = jax.random.normal(jax.random.PRNGKey(52), (bs, s, kv, d)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(53), (bs, s, kv, d)) * 0.5
    kw8, ke8 = quant_pack_kv_rows(k, 8)
    vw8, ve8 = quant_pack_kv_rows(v, 8)
    off = jnp.asarray([s - t, s - t - 16], jnp.int32)
    args = dict(causal=True, q_offset=off, bk=bk)
    ok = _routed("kernel", ops.flash_attention_packed, q, kw8, ke8, vw8,
                 ve8, kv_active_bits=b, **args)
    assert ops.last_fap_route()[0] == "kernel"
    of = _routed("fallback", ops.flash_attention_packed, q, kw8, ke8, vw8,
                 ve8, kv_active_bits=b, **args)
    assert ops.last_fap_route()[0] == "fallback"
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(of))
    t_shift = 8 - b
    face = ops.flash_attention_packed(
        q, plane_prefix_words(kw8, 8, b),
        (ke8.astype(jnp.int32) + t_shift).astype(jnp.int8),
        plane_prefix_words(vw8, 8, b),
        (ve8.astype(jnp.int32) + t_shift).astype(jnp.int8), **args)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(face))


def test_paged_kv_trunc_mixed_lanes_vs_per_lane_planar():
    """The traced per-sequence plane-shift vector: lane 0 reads 4-bit,
    lane 1 full width, from ONE 8-bit pool in one call. Kernel and
    fallback routes agree, and each lane equals a solo planar call at its
    static width."""
    bs, t, h, kv, d, s, page = 2, 8, 4, 2, 32, 128, 64
    maxp = s // page
    n_pages = paging.FIRST_PAGE + bs * maxp
    k = jax.random.normal(jax.random.PRNGKey(61), (bs, s, kv, d)) * 0.5
    v = jax.random.normal(jax.random.PRNGKey(62), (bs, s, kv, d)) * 0.5
    kw, ke = quant_pack_kv_rows(k, 8)
    vw, ve = quant_pack_kv_rows(v, 8)
    rng = np.random.default_rng(63)
    pt = rng.permutation(np.arange(paging.FIRST_PAGE, n_pages)).reshape(
        bs, maxp).astype(np.int32)

    def pool(x):
        p = np.zeros((n_pages, page) + x.shape[2:], np.asarray(x).dtype)
        xn = np.asarray(x).reshape(bs, maxp, page, *x.shape[2:])
        for i in range(bs):
            for j in range(maxp):
                p[pt[i, j]] = xn[i, j]
        return jnp.asarray(p)

    kpw, kpe, vpw, vpe = pool(kw), pool(ke), pool(vw), pool(ve)
    q = jax.random.normal(jax.random.PRNGKey(64), (bs, t, h, d))
    off = jnp.asarray([s - t, s - t - 16], jnp.int32)
    tr = jnp.asarray([4, 0], jnp.int32)          # widths 4 and 8
    args = dict(causal=True, q_offset=off, kv_trunc=tr)
    ok = _routed("kernel", ops.flash_attention_paged, q, kpw, kpe, vpw,
                 vpe, jnp.asarray(pt), **args)
    assert ops.last_paged_route()[0] == "kernel"
    oj = _routed("fallback", ops.flash_attention_paged, q, kpw, kpe, vpw,
                 vpe, jnp.asarray(pt), **args)
    assert ops.last_paged_route()[0] == "fallback"
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(oj))
    for lane, width in enumerate([4, 8]):
        solo = ops.flash_attention_packed(
            q[lane:lane + 1], kw[lane:lane + 1], ke[lane:lane + 1],
            vw[lane:lane + 1], ve[lane:lane + 1], causal=True,
            q_offset=off[lane:lane + 1], bk=page,
            kv_active_bits=None if width == 8 else width)
        np.testing.assert_array_equal(np.asarray(ok[lane]),
                                      np.asarray(solo[0]))


# ---------------- checkpoint: restore(bits=b) -------------------------------

@pytest.mark.parametrize("b", [2, 5, 8])
def test_checkpoint_restore_bits_matches_with_bits(tmp_path, b):
    """Plane-prefix load: restoring a full-width checkpoint at width b
    yields exactly ``with_bits(b)`` of every packed leaf (words and
    dequant), without touching fp leaves."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.core.gse import PackedGSETensor
    from repro.kernels.ops import gse_quantize_pack
    rng = np.random.default_rng(3)
    w1 = jnp.asarray(rng.standard_normal((8, 96)), jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    tree = {"w1": gse_quantize_pack(w1, 8, 32),
            "nested": {"m": gse_quantize_pack(w2, 8, 32)},
            "fp": jnp.ones((3,), jnp.float32)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    view, _, _ = mgr.restore(1, tree, bits=b)
    for got, src in ((view["w1"], tree["w1"]),
                     (view["nested"]["m"], tree["nested"]["m"])):
        want = src.with_bits(b)
        assert isinstance(got, PackedGSETensor)
        assert got.bits == b and got.stored_bits == 8
        np.testing.assert_array_equal(np.asarray(got.mantissa_words),
                                      np.asarray(want.mantissa_words))
        np.testing.assert_array_equal(np.asarray(got.dequantize()),
                                      np.asarray(want.dequantize()))
    np.testing.assert_array_equal(np.asarray(view["fp"]),
                                  np.ones((3,), np.float32))


def test_checkpoint_lossy_snapshot_narrows(tmp_path):
    """A lossy ``gse_bits=8`` float snapshot restores narrowed too — the
    fp leaf comes back as the b-bit view's dequant, and actually differs
    from the full-width restore."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.kernels.ops import gse_quantize_pack
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((8, 96)), jnp.float32)
    tree = {"w": w}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree, gse_bits=8, gse_min_size=1)
    r8, _, _ = mgr.restore(1, tree)
    r4, _, _ = mgr.restore(1, tree, bits=4)
    want4 = gse_quantize_pack(w, 8, 32).with_bits(4).dequantize()
    np.testing.assert_array_equal(np.asarray(r4["w"]), np.asarray(want4))
    assert not np.array_equal(np.asarray(r4["w"]), np.asarray(r8["w"]))
