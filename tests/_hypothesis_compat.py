"""Graceful degradation for the property-test suite.

When ``hypothesis`` is installed (CI: ``pip install -e .[test]``) this module
re-exports the real ``given`` / ``settings`` / ``strategies``. In a bare
environment the import used to kill collection of five test modules
(`ModuleNotFoundError` at collect time); instead we fall back to a tiny
deterministic sampler so the modules still collect AND their property tests
still run as a reduced sweep: each ``@given`` test executes over a fixed
number of seeded draws, with strategy endpoints (lo/hi, first/last element)
always included in the first draws.

The fallback implements only what this repo's tests use —
``st.integers(lo, hi)``, ``st.floats(lo, hi)``, ``st.sampled_from(seq)``,
``st.booleans()`` — and ``settings(max_examples=..., deadline=...)``. A test
that genuinely needs full hypothesis semantics (shrinking, assume, etc.)
should ``pytest.importorskip("hypothesis")`` at module top instead.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # bare env: deterministic reduced sweep
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 8     # per-test cap; endpoints come first

    class _Strategy:
        def __init__(self, draw, endpoints=()):
            self._draw = draw
            self._endpoints = list(endpoints)

        def example_at(self, rng, i):
            if i < len(self._endpoints):
                return self._endpoints[i]
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value),
                             endpoints=(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value),
                             endpoints=(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            xs = list(elements)
            return _Strategy(lambda r: r.choice(xs), endpoints=xs[:2])

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)),
                             endpoints=(False, True))

    st = _Strategies()

    def settings(max_examples=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._compat_max_examples = min(max_examples,
                                              _FALLBACK_EXAMPLES)
            return fn
        return deco

    def given(**strategy_kw):
        def deco(fn):
            # zero-arg wrapper: the drawn kwargs must NOT look like pytest
            # fixtures, so the original signature is deliberately hidden
            # (no functools.wraps -- it forwards __wrapped__/signature).
            def runner():
                n = getattr(runner, "_compat_max_examples",
                            _FALLBACK_EXAMPLES)
                rng = random.Random(0xC0FFEE)
                for i in range(n):
                    draws = {k: s.example_at(rng, i)
                             for k, s in strategy_kw.items()}
                    fn(**draws)
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner
        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
