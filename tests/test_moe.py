"""MoE sort-based dispatch: equivalence with dense routing at ample
capacity; capacity-drop behavior; expert utilization."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import QuantPolicy
from repro.models.config import ModelConfig
from repro.models.layers import moe_init, moe_apply

FP = QuantPolicy(fmt="none", a_bits=None, w_bits=None, g_bits=None,
                 adapter_bits=None, base_w_nf4=False, rank=0)

CFG = ModelConfig(family="moe", d_model=64, n_experts=4, top_k=2,
                  moe_d_ff=32, act="silu", capacity_factor=4.0)


def _dense_reference(fz, x, cfg):
    """One-hot dense MoE (no capacity) — the exact combine target."""
    b, t, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ fz["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    wg = fz["w_gate"].dequantize(jnp.float32)
    wu = fz["w_up"].dequantize(jnp.float32)
    wd = fz["w_down"].dequantize(jnp.float32)
    y = jnp.zeros_like(xf, dtype=jnp.float32)
    for kk in range(cfg.top_k):
        for e in range(cfg.n_experts):
            sel = (eidx[:, kk] == e).astype(jnp.float32)[:, None]
            h = jax.nn.silu(xf.astype(jnp.float32) @ wg[e]) \
                * (xf.astype(jnp.float32) @ wu[e])
            y = y + sel * gate[:, kk:kk + 1] * (h @ wd[e])
    return y.reshape(b, t, d)


def test_matches_dense_reference_with_ample_capacity():
    fz, tr = moe_init(jax.random.PRNGKey(0), CFG, FP)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64),
                          jnp.float32)
    y = moe_apply(fz, tr, x.astype(jnp.bfloat16), CFG, FP)
    yref = _dense_reference(fz, x, CFG)
    rel = float(jnp.max(jnp.abs(y.astype(jnp.float32) - yref))
                / (jnp.max(jnp.abs(yref)) + 1e-9))
    assert rel < 0.05, rel      # bf16 grouped-GEMM tolerance


def test_capacity_drop_zeroes_overflow():
    """cf -> tiny: most copies dropped, output must shrink, never NaN."""
    cfg = dataclasses.replace(CFG, capacity_factor=0.05)
    fz, tr = moe_init(jax.random.PRNGKey(2), cfg, FP)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 64),
                          jnp.bfloat16)
    y = moe_apply(fz, tr, x, cfg, FP)
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
    cfg_full = dataclasses.replace(CFG, capacity_factor=8.0)
    y_full = moe_apply(fz, tr, x, cfg_full, FP)
    assert float(jnp.mean(jnp.abs(y))) < float(jnp.mean(jnp.abs(y_full)))


def test_grad_flows_through_dispatch():
    pol = QuantPolicy.gsq(8, rank=4)
    cfg = dataclasses.replace(CFG)
    fz, tr = moe_init(jax.random.PRNGKey(4), cfg, pol)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 64), jnp.bfloat16)

    def loss(x):
        return jnp.sum(moe_apply(fz, tr, x, cfg, pol).astype(jnp.float32)
                       ** 2)

    g = jax.grad(loss)(x)
    assert bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
    assert float(jnp.abs(g.astype(jnp.float32)).sum()) > 0
