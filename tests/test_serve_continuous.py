"""Continuous-batching engine: per-request token identity against solo
``greedy_generate`` (the whole point of the bit-exact paged substrate),
page recycling under mid-flight admission/eviction, backpressure, and the
per-request sampling/stop controls."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import QuantPolicy
from repro.models import model as M
from repro.serve import engine as E
from repro.serve import paging
from repro.serve.scheduler import (ContinuousBatchingEngine, Request,
                                   SamplingParams)

FP = QuantPolicy(base_w_nf4=False, a_bits=None, w_bits=None, g_bits=None,
                 adapter_bits=None, fmt="none", rank=8)

PAGE, MAXP, SLOTS = 8, 4, 2
S_CAP = PAGE * MAXP


@pytest.fixture(scope="module")
def model():
    cfg = reduced_config("granite_3_2b")
    fz, tr = M.init_model(jax.random.PRNGKey(0), cfg, FP)
    return cfg, fz, tr


def _requests(cfg, spec):
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(4, cfg.vocab,
                                        size=(t,)).astype(np.int32),
                    max_new=mn)
            for i, (t, mn) in enumerate(spec)]


def _engine(model, kv_bits, **kw):
    cfg, fz, tr = model
    args = dict(slots=SLOTS, page_size=PAGE, max_pages_per_slot=MAXP,
                kv_quant_bits=kv_bits)
    args.update(kw)
    return ContinuousBatchingEngine(fz, tr, cfg, FP, **args)


@pytest.mark.parametrize("kv_bits", [None, 8, 4])
def test_per_request_token_identity_vs_solo(model, kv_bits):
    """Acceptance: every request decoded through the engine — admitted
    and evicted mid-flight, pages recycled from earlier requests — emits
    **exactly** the tokens of its solo greedy_generate run at cache
    length s_cap, on the fp cache and at kv_quant_bits 8 and 4. Five
    ragged requests over two slots force admission, eviction and page
    recycling while other lanes are mid-decode."""
    cfg, fz, tr = model
    reqs = _requests(cfg, [(12, 10), (4, 3), (6, 8), (5, 2), (9, 6)])
    eng = _engine(model, kv_bits)
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    assert eng.summary()["admitted"] == eng.summary()["evicted"] == 5
    for r in reqs:
        solo = E.greedy_generate(fz, tr, jnp.asarray(r.prompt)[None], cfg,
                                 FP, max_new=r.max_new, max_len=S_CAP,
                                 kv_quant_bits=kv_bits)
        np.testing.assert_array_equal(res[r.uid], np.asarray(solo[0]))
    if kv_bits is not None:
        # everything evicted: the whole pool is back on the free list
        assert eng.allocator.utilization() == 0.0
        assert eng.allocator.n_free == eng.allocator.n_allocatable


def test_page_recycling_exercises_recycled_pages(model):
    """With a 3-page pool, 4 admissions needing 6 page-spans MUST reuse
    freed pages; identity (asserted above) plus this proves recycled
    pages carry no residue. Here we assert the recycling happened."""
    cfg, fz, tr = model
    reqs = _requests(cfg, [(8, 6), (4, 4), (6, 5), (5, 3)])
    eng = _engine(model, 8, n_pages=paging.FIRST_PAGE + 3)
    seen_pages = []
    for r in reqs:
        eng.submit(r)
    orig_evict = eng._evict

    def spy(slot):
        seen_pages.append(tuple(eng.active[slot].pages))
        orig_evict(slot)
    eng._evict = spy
    eng.run()
    used = [p for span in seen_pages for p in span]
    assert len(used) > len(set(used))       # some physical page reused
    assert paging.NULL_PAGE not in used and paging.TRASH_PAGE not in used


def test_backpressure_serializes_when_pool_too_small(model):
    """A pool that fits only one request's span at a time: the second
    request waits (alloc -> None) and is served after the first evicts —
    nothing crashes, tokens still match solo runs."""
    cfg, fz, tr = model
    reqs = _requests(cfg, [(8, 4), (9, 4)])
    eng = _engine(model, 8, n_pages=paging.FIRST_PAGE + 2)  # 2 pages usable
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    assert set(res) == {0, 1}
    assert eng.summary()["occupancy"] <= 0.5 + 1e-9   # never both active
    for r in reqs:
        solo = E.greedy_generate(fz, tr, jnp.asarray(r.prompt)[None], cfg,
                                 FP, max_new=r.max_new, max_len=S_CAP,
                                 kv_quant_bits=8)
        np.testing.assert_array_equal(res[r.uid], np.asarray(solo[0]))


def test_submit_validation(model):
    eng = _engine(model, 8)
    with pytest.raises(ValueError):          # doesn't fit a slot
        eng.submit(Request(uid=0, prompt=np.zeros((30,), np.int32),
                           max_new=10))
    with pytest.raises(ValueError):          # more pages than the pool has
        eng.submit(Request(uid=1, prompt=np.zeros((4,), np.int32),
                           max_new=S_CAP + PAGE))


def test_stop_token_stops_at_first_occurrence(model):
    cfg, fz, tr = model
    req = _requests(cfg, [(12, 10)])[0]
    solo = np.asarray(E.greedy_generate(
        fz, tr, jnp.asarray(req.prompt)[None], cfg, FP, max_new=10,
        max_len=S_CAP, kv_quant_bits=8)[0])
    stop = int(solo[2])
    first = int(np.argmax(solo == stop))     # stop fires at FIRST hit
    eng = _engine(model, 8)
    eng.submit(Request(uid=0, prompt=req.prompt, max_new=10,
                       stop_token=stop))
    out = eng.run()[0]
    assert out[-1] == stop and len(out) == first + 1
    np.testing.assert_array_equal(out, solo[:first + 1])


def test_sampling_deterministic_and_varied(model):
    """Temperature sampling is reproducible (uid/step/seed reseeding) and
    actually diverges from greedy; different seeds decorrelate."""
    cfg, fz, tr = model
    req = _requests(cfg, [(12, 12)])[0]

    def run(sp):
        eng = _engine(model, 8)
        eng.submit(Request(uid=7, prompt=req.prompt, max_new=12,
                           sampling=sp))
        return eng.run()[7]

    hot = SamplingParams(temperature=1.5, top_k=0, seed=1)
    a, b = run(hot), run(hot)
    np.testing.assert_array_equal(a, b)
    c = run(SamplingParams(temperature=1.5, top_k=0, seed=2))
    greedy = run(SamplingParams())
    assert not np.array_equal(a, greedy) or not np.array_equal(c, greedy)
    # top-k=1 at any temperature is greedy
    np.testing.assert_array_equal(
        run(SamplingParams(temperature=2.0, top_k=1, seed=3)), greedy)


def test_mixed_kv_bits_token_identity_vs_solo(model):
    """Progressive precision through the engine: five requests at
    per-request read widths (4/full/6/8/3-bit) share two slots and ONE
    8-bit page pool, admitted and evicted mid-flight — and every stream
    is exactly its solo run at the same ``kv_active_bits``. One compiled
    executable serves all widths (the per-sequence plane shift is a
    traced scalar-prefetch lane, not a retrace)."""
    cfg, fz, tr = model
    spec = [(12, 10, 4), (4, 3, None), (6, 8, 6), (5, 2, 8), (9, 6, 3)]
    base = _requests(cfg, [(t, mn) for t, mn, _ in spec])
    reqs = [Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new,
                    sampling=SamplingParams(kv_bits=kb))
            for r, (_, _, kb) in zip(base, spec)]
    eng = _engine(model, 8)
    for r in reqs:
        eng.submit(r)
    res = eng.run()
    assert eng.summary()["admitted"] == eng.summary()["evicted"] == 5
    for r, (_, _, kb) in zip(reqs, spec):
        solo = E.greedy_generate(fz, tr, jnp.asarray(r.prompt)[None], cfg,
                                 FP, max_new=r.max_new, max_len=S_CAP,
                                 kv_quant_bits=8, kv_active_bits=kb)
        np.testing.assert_array_equal(res[r.uid], np.asarray(solo[0]))
    # the narrow widths are real: at least one narrowed stream diverges
    # from its full-width decode
    full = {r.uid: np.asarray(E.greedy_generate(
        fz, tr, jnp.asarray(r.prompt)[None], cfg, FP, max_new=r.max_new,
        max_len=S_CAP, kv_quant_bits=8)[0]) for r in reqs}
    assert any(not np.array_equal(res[r.uid], full[r.uid])
               for r, (_, _, kb) in zip(reqs, spec)
               if kb not in (None, 8))


def test_submit_validates_kv_bits(model):
    """Width validation happens at intake (bounce one request), never at
    trace time (poison the shared executable): out-of-range widths and
    kv_bits against an fp-cache engine are rejected; the pool width
    itself is accepted."""
    eng = _engine(model, 8)
    cfg, _, _ = model
    def req(uid, kb):
        return Request(uid=uid, prompt=np.asarray([5, 6, 7], np.int32),
                       max_new=2, sampling=SamplingParams(kv_bits=kb))
    eng.submit(req(0, 8))                        # pool width: fine
    eng.submit(req(1, 2))                        # narrowest legal width
    for bad in (1, 9):
        with pytest.raises(ValueError, match="kv_bits"):
            eng.submit(req(2, bad))
    fp_eng = _engine(model, None)
    with pytest.raises(ValueError, match="fp cache"):
        fp_eng.submit(req(3, 4))
