"""Serving engine: prefill/decode consistency vs teacher forcing, greedy
generation, cache bookkeeping — per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import QuantPolicy
from repro.models import model as M
from repro.serve import engine as E

# full-precision policy isolates decode-path bugs from quantization noise
FP = QuantPolicy(base_w_nf4=False, a_bits=None, w_bits=None, g_bits=None,
                 adapter_bits=None, fmt="none", rank=8)

FAMS = ["granite_3_2b", "mamba2_2_7b", "hymba_1_5b", "whisper_small",
        "granite_moe_1b_a400m"]


def _setup(arch):
    cfg = reduced_config(arch)
    fz, tr = M.init_model(jax.random.PRNGKey(0), cfg, FP)
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (2, 8), 4, cfg.vocab)
    extra = {}
    if cfg.is_encoder_decoder:
        extra["frames"] = jax.random.normal(
            key, (2, cfg.encoder_len, cfg.d_model))
    return cfg, fz, tr, prompt, extra


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_teacher_forcing(arch):
    cfg, fz, tr, prompt, extra = _setup(arch)
    cache = E.init_decode_cache(
        cfg, 2, 16,
        enc_len=cfg.encoder_len if cfg.is_encoder_decoder else None)
    logits, cache = E.prefill(fz, tr, dict(tokens=prompt, **extra), cache,
                              cfg, FP)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache = E.decode_step(fz, tr, tok, cache, cfg, FP)
    ref = M.forward(fz, tr,
                    dict(tokens=jnp.concatenate([prompt, tok], 1), **extra),
                    cfg, FP)[:, -1]
    rel = float(jnp.max(jnp.abs(logits2 - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.05, rel         # bf16 path reordering tolerance


@pytest.mark.parametrize("arch", ["granite_3_2b", "mamba2_2_7b"])
def test_greedy_generate(arch):
    cfg, fz, tr, prompt, extra = _setup(arch)
    if extra:
        pytest.skip("generate driver is decoder-only")
    out = E.greedy_generate(fz, tr, prompt, cfg, FP, max_new=5)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < cfg.padded_vocab)))


@pytest.mark.parametrize("kv_quant_bits", [None, 6])
def test_greedy_generate_max_new_1(kv_quant_bits):
    """Degenerate decode: max_new=1 runs a zero-length scan — the driver
    must still return a (B, 1) token array on both the packed and
    unpacked cache paths."""
    cfg, fz, tr, prompt, extra = _setup("granite_3_2b")
    out = E.greedy_generate(fz, tr, prompt, cfg, FP, max_new=1,
                            kv_quant_bits=kv_quant_bits)
    assert out.shape == (2, 1)
    assert bool(jnp.all((out >= 0) & (out < cfg.padded_vocab)))
    # matches the first token of a longer decode
    ref = E.greedy_generate(fz, tr, prompt, cfg, FP, max_new=3)
    np.testing.assert_array_equal(np.asarray(out[:, 0]),
                                  np.asarray(ref[:, 0]))


def test_packed_cache_repack_is_bit_identical_mid_scan():
    """pack -> unpack -> pack of an already-GSE-valued cache reproduces
    the packed words exactly — the invariant that lets greedy_generate
    carry the cache packed through the decode scan without accumulating
    error on old positions."""
    cfg, fz, tr, prompt, extra = _setup("granite_3_2b")
    cache = E.init_decode_cache(cfg, 2, 16)
    _, cache = E.prefill(fz, tr, {"tokens": prompt}, cache, cfg, FP)
    p1 = E.pack_decode_cache(cache, bits=6)
    p2 = E.pack_decode_cache(E.unpack_decode_cache(p1), bits=6)
    for key in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(p1[key].mantissa_words),
                                      np.asarray(p2[key].mantissa_words))
        np.testing.assert_array_equal(np.asarray(p1[key].exponent_words),
                                      np.asarray(p2[key].exponent_words))


def test_kv_pack_group_non_divisible_head_dim():
    """head_dim % group != 0 falls back to the largest divisor <= group
    (one exponent per 20 values for head_dim 40), not one exponent per
    whole head — strictly finer grouping, strictly less error."""
    assert E._kv_pack_group(40, 32) == 20
    assert E._kv_pack_group(64, 32) == 32
    assert E._kv_pack_group(8, 32) == 8
    key = jax.random.PRNGKey(0)
    cache = {"k": jax.random.normal(key, (1, 2, 4, 2, 40)) * 0.5,
             "v": jax.random.normal(jax.random.PRNGKey(1),
                                    (1, 2, 4, 2, 40)) * 0.5,
             "index": jnp.zeros((1,), jnp.int32)}
    packed = E.pack_decode_cache(cache, bits=6)
    assert packed["k"].group_size == 20
    back = E.unpack_decode_cache(packed)
    err = float(jnp.max(jnp.abs(back["k"] - cache["k"])))
    # per-20-value exponents: error bounded by half an ulp of each group
    # scale; the old whole-head fallback is strictly coarser
    from repro.core.gse import gse_fake_quant
    np.testing.assert_array_equal(
        np.asarray(back["k"]),
        np.asarray(gse_fake_quant(cache["k"], 6, 20).astype(jnp.bfloat16)
                   .astype(jnp.float32)))
    assert err < 0.1


def test_cache_index_advances():
    cfg, fz, tr, prompt, extra = _setup("granite_3_2b")
    cache = E.init_decode_cache(cfg, 2, 16)
    # per-sequence index: one (L, B) counter so ragged batches can advance
    # each row independently
    assert cache["index"].shape == (cfg.n_layers, 2)
    _, cache = E.prefill(fz, tr, {"tokens": prompt}, cache, cfg, FP)
    assert np.all(np.asarray(cache["index"]) == 8)
    tok = jnp.zeros((2, 1), jnp.int32)
    _, cache = E.decode_step(fz, tr, tok, cache, cfg, FP)
    assert np.all(np.asarray(cache["index"]) == 9)


def test_quantized_decode_consistent_with_quantized_forward():
    """Under GSQ policy both paths share the same QCD math — outputs agree
    within quantization-noise tolerance."""
    pol = QuantPolicy.gsq(8, rank=8)
    cfg = reduced_config("granite_3_2b")
    fz, tr = M.init_model(jax.random.PRNGKey(3), cfg, pol)
    prompt = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 4, cfg.vocab)
    cache = E.init_decode_cache(cfg, 2, 16)
    logits, cache = E.prefill(fz, tr, {"tokens": prompt}, cache, cfg, pol)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, _ = E.decode_step(fz, tr, tok, cache, cfg, pol)
    ref = M.forward(fz, tr, {"tokens": jnp.concatenate([prompt, tok], 1)},
                    cfg, pol)[:, -1]
    rel = float(jnp.max(jnp.abs(logits2 - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.25
