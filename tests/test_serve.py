"""Serving engine: prefill/decode consistency vs teacher forcing, greedy
generation, cache bookkeeping — per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.policy import QuantPolicy
from repro.models import model as M
from repro.serve import engine as E

# full-precision policy isolates decode-path bugs from quantization noise
FP = QuantPolicy(base_w_nf4=False, a_bits=None, w_bits=None, g_bits=None,
                 adapter_bits=None, fmt="none", rank=8)

FAMS = ["granite_3_2b", "mamba2_2_7b", "hymba_1_5b", "whisper_small",
        "granite_moe_1b_a400m"]


def _setup(arch):
    cfg = reduced_config(arch)
    fz, tr = M.init_model(jax.random.PRNGKey(0), cfg, FP)
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (2, 8), 4, cfg.vocab)
    extra = {}
    if cfg.is_encoder_decoder:
        extra["frames"] = jax.random.normal(
            key, (2, cfg.encoder_len, cfg.d_model))
    return cfg, fz, tr, prompt, extra


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_teacher_forcing(arch):
    cfg, fz, tr, prompt, extra = _setup(arch)
    cache = E.init_decode_cache(
        cfg, 2, 16,
        enc_len=cfg.encoder_len if cfg.is_encoder_decoder else None)
    logits, cache = E.prefill(fz, tr, dict(tokens=prompt, **extra), cache,
                              cfg, FP)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache = E.decode_step(fz, tr, tok, cache, cfg, FP)
    ref = M.forward(fz, tr,
                    dict(tokens=jnp.concatenate([prompt, tok], 1), **extra),
                    cfg, FP)[:, -1]
    rel = float(jnp.max(jnp.abs(logits2 - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.05, rel         # bf16 path reordering tolerance


@pytest.mark.parametrize("arch", ["granite_3_2b", "mamba2_2_7b"])
def test_greedy_generate(arch):
    cfg, fz, tr, prompt, extra = _setup(arch)
    if extra:
        pytest.skip("generate driver is decoder-only")
    out = E.greedy_generate(fz, tr, prompt, cfg, FP, max_new=5)
    assert out.shape == (2, 5)
    assert bool(jnp.all((out >= 0) & (out < cfg.padded_vocab)))


def test_cache_index_advances():
    cfg, fz, tr, prompt, extra = _setup("granite_3_2b")
    cache = E.init_decode_cache(cfg, 2, 16)
    _, cache = E.prefill(fz, tr, {"tokens": prompt}, cache, cfg, FP)
    assert int(cache["index"][0]) == 8
    tok = jnp.zeros((2, 1), jnp.int32)
    _, cache = E.decode_step(fz, tr, tok, cache, cfg, FP)
    assert int(cache["index"][0]) == 9


def test_quantized_decode_consistent_with_quantized_forward():
    """Under GSQ policy both paths share the same QCD math — outputs agree
    within quantization-noise tolerance."""
    pol = QuantPolicy.gsq(8, rank=8)
    cfg = reduced_config("granite_3_2b")
    fz, tr = M.init_model(jax.random.PRNGKey(3), cfg, pol)
    prompt = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 4, cfg.vocab)
    cache = E.init_decode_cache(cfg, 2, 16)
    logits, cache = E.prefill(fz, tr, {"tokens": prompt}, cache, cfg, pol)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, _ = E.decode_step(fz, tr, tok, cache, cfg, pol)
    ref = M.forward(fz, tr, {"tokens": jnp.concatenate([prompt, tok], 1)},
                    cfg, pol)[:, -1]
    rel = float(jnp.max(jnp.abs(logits2 - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.25
