"""NF4 + FP8 format tests (QLoRA substrate; paper Tab. 2 baseline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# Real hypothesis when installed; deterministic reduced sweep otherwise
# (keeps collection green in bare environments -- see _hypothesis_compat).
from _hypothesis_compat import given, settings, st

from repro.core.nf4 import (BLOCK, NF4_CODE, nf4_dequantize, nf4_fake_quant,
                            nf4_quantize)
from repro.core.fp8 import fp8_fake_quant, fp8_quantization_error
from repro.core.gse import quantization_error


def test_nf4_roundtrip_error():
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 256)) * 0.02
    wd = nf4_fake_quant(w, jnp.float32)
    rel = float(jnp.sqrt(jnp.mean((w - wd) ** 2)) / jnp.std(w))
    assert rel < 0.15          # NF4 sits ~0.08-0.10 on gaussians


def test_nf4_codes_keep_weight_shape():
    w = jnp.ones((64, 128))
    t = nf4_quantize(w)
    assert t.codes.shape == (64, 128)


def test_nf4_exact_on_codebook_values():
    """Values that are exactly absmax*code roundtrip exactly."""
    code = jnp.asarray(NF4_CODE)
    w = (code[jax.random.randint(jax.random.PRNGKey(1), (4, BLOCK), 0, 16)]
         * 0.05)
    wd = nf4_fake_quant(w, jnp.float32)
    np.testing.assert_allclose(np.asarray(wd), np.asarray(w), atol=2e-4)


def test_nf4_packed_bytes_half_of_int8():
    w = jnp.ones((256, 256))
    t = nf4_quantize(w)
    assert t.nbytes_packed() < w.size * 0.6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 10.0))
def test_nf4_property_bounded_by_blockmax(seed, scale):
    w = jax.random.normal(jax.random.PRNGKey(seed), (8, 64)) * scale
    wd = nf4_fake_quant(w, jnp.float32)
    blocks = w.reshape(-1, BLOCK)
    bd = wd.reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    # dequantized values never exceed the block absmax (plus DQ noise)
    assert bool(jnp.all(jnp.abs(bd) <= amax * 1.05 + 1e-6))


def test_fp8_formats():
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 128))
    for fmt in ("e4m3", "e5m2"):
        y = fp8_fake_quant(x, fmt, 32)
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y)))
    # e4m3 has more mantissa -> lower error
    e43 = float(fp8_quantization_error(x, "e4m3")["mse"])
    e52 = float(fp8_quantization_error(x, "e5m2")["mse"])
    assert e43 < e52


def test_paper_claim_gse8_beats_fp8():
    """Paper Tab. 2: GSE-INT8 > FP8 at equal bits on real-ish tensors."""
    x = jax.random.normal(jax.random.PRNGKey(3), (256, 512)) * 0.5
    gse8 = float(quantization_error(x, 8)["sqnr_db"])
    fp8 = float(fp8_quantization_error(x, "e4m3")["sqnr_db"])
    assert gse8 > fp8 + 3.0    # comfortably better on gaussian data
