"""Data pipeline, 8-bit optimizer, checkpoint manager, gradient
compression, sharding rules."""
import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import (DataConfig, PrefetchingLoader,
                                 batch_at_step)
from repro.optim.adamw8bit import AdamW8bit
from repro.checkpoint.manager import CheckpointManager
from repro.distributed.sharding import (ShardingRules, resolve_pspec,
                                        shard_map_compat, strip_axes)


# ---------------- data -----------------------------------------------------

def test_data_deterministic_and_step_pure():
    cfg = DataConfig(vocab=100, seq_len=64, global_batch=4)
    b1 = batch_at_step(cfg, 7)
    b2 = batch_at_step(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at_step(cfg, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=50, seq_len=32, global_batch=2)
    b = batch_at_step(cfg, 0)
    assert b["tokens"].shape == (2, 32)
    assert b["labels"].shape == (2, 32)
    assert b["loss_mask"].shape == (2, 32)
    assert set(np.unique(b["loss_mask"])) <= {0.0, 1.0}


def test_data_host_striping_partitions_batch():
    cfg = DataConfig(vocab=50, seq_len=32, global_batch=4)
    full = batch_at_step(cfg, 3)["tokens"]
    h0 = batch_at_step(dataclasses.replace(cfg, host_id=0, num_hosts=2),
                       3)["tokens"]
    h1 = batch_at_step(dataclasses.replace(cfg, host_id=1, num_hosts=2),
                       3)["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0, h1]), full)


def test_prefetch_loader_resumes():
    cfg = DataConfig(vocab=50, seq_len=32, global_batch=2, prefetch=2)
    it = PrefetchingLoader(cfg, start_step=5)
    b = next(it)
    it.close()
    np.testing.assert_array_equal(b["tokens"],
                                  batch_at_step(cfg, 5)["tokens"])


# ---------------- optimizer ------------------------------------------------

def test_adamw8bit_converges_quadratic():
    opt = AdamW8bit(lr=0.1, warmup_steps=1)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}           # d/dw ||w||^2
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_adamw8bit_close_to_fp32_adam():
    """8-bit states track an exact fp32 AdamW on a *fixed* gradient
    sequence (param-dependent grads would make the comparison chaotic)."""
    rng = np.random.default_rng(0)
    gseq = rng.normal(size=(30, 512)).astype(np.float32)
    opt = AdamW8bit(lr=0.05, warmup_steps=1)
    p8 = {"w": jnp.linspace(-1, 1, 512)}
    s8 = opt.init(p8)
    pf = np.linspace(-1, 1, 512)
    m = np.zeros(512)
    v = np.zeros(512)
    for t in range(1, 31):
        g = gseq[t - 1]
        p8, s8 = opt.update({"w": jnp.asarray(g)}, s8, p8)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh, vh = m / (1 - 0.9 ** t), v / (1 - 0.999 ** t)
        pf = pf - 0.05 * mh / (np.sqrt(vh) + 1e-8)
    err = float(np.max(np.abs(np.asarray(p8["w"]) - pf)))
    # 30 steps x lr 0.05: total movement ~1.5; 8-bit state noise stays
    # a small fraction of it
    assert err < 0.15, err


def test_adamw8bit_state_is_int8():
    opt = AdamW8bit()
    params = {"a": jnp.ones((1000,))}
    st_ = opt.init(params)
    assert st_.m_q["a"].dtype == jnp.int8
    assert opt.state_nbytes(st_) < 1000 * 4   # far below fp32 moments


# ---------------- checkpoint ------------------------------------------------

def test_checkpoint_roundtrip_mixed_dtypes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.int8).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "d": jnp.float32(2.5)}}
    mgr.save(3, tree, metadata={"note": "x"})
    out, meta, step = mgr.restore(3, tree)
    assert step == 3 and meta["note"] == "x"
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["b"]["c"], np.float32), 1.5)


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest() == 4


def test_checkpoint_nf4_tree_roundtrip(tmp_path):
    from repro.core.nf4 import nf4_quantize
    mgr = CheckpointManager(str(tmp_path), keep=1)
    t = nf4_quantize(jax.random.normal(jax.random.PRNGKey(0), (64, 64)))
    tree = {"w": t}
    mgr.save(1, tree)
    out, _, _ = mgr.restore(1, tree)
    np.testing.assert_array_equal(np.asarray(out["w"].codes),
                                  np.asarray(t.codes))
    np.testing.assert_allclose(np.asarray(out["w"].dequantize(jnp.float32)),
                               np.asarray(t.dequantize(jnp.float32)))


# ---------------- sharding rules -------------------------------------------

class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_resolve_pspec_divisibility_guard():
    mesh = _FakeMesh({"data": 4, "model": 8})
    rules = ShardingRules.single_pod()
    # 12 heads on 8-way model -> replicate; 64 ff divisible -> shard
    spec = resolve_pspec((2, 12, 64), (None, "heads", "ff"), mesh, rules)
    assert spec[1] is None and spec[2] == "model"


def test_resolve_pspec_no_axis_reuse():
    mesh = _FakeMesh({"data": 4, "model": 8})
    rules = ShardingRules(batch=("data",), ff="model", heads="model")
    spec = resolve_pspec((8, 16, 64), ("batch", "heads", "ff"), mesh, rules)
    # heads claims model first; ff must then replicate
    assert spec[1] == "model" and spec[2] is None


def test_strip_axes():
    rules = ShardingRules()            # batch=("pod","data")
    s = strip_axes(rules, "pod")
    assert s.batch == "data"
    s2 = strip_axes(rules, "pod", "data")
    assert s2.batch is None


# ---------------- gradient compression --------------------------------------

def test_compressed_mean_single_shard_semantics():
    """shard_map over a size-1 axis: compressed mean == quantized value and
    the residual captures exactly the quantization error."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compressed_mean
    mesh = jax.make_mesh((1,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 1e-3
    r0 = jnp.zeros((256,))

    def f(g, r):
        return compressed_mean(g[0], r[0], "pod", bits=8, group=32)

    out, res = shard_map_compat(
        f, mesh, in_specs=(P("pod"), P("pod")),
        out_specs=(P(), P()))(g[None], r0[None])
    np.testing.assert_allclose(np.asarray(out + res), np.asarray(g),
                               atol=1e-7)


def test_error_feedback_reduces_bias():
    """Repeatedly syncing the same gradient with error feedback: the
    accumulated transmitted mass approaches the true value."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compressed_mean
    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.full((32,), 1e-6)     # deep below one 8-bit step of its group
    r = jnp.zeros((32,))
    sent = jnp.zeros((32,))

    def f(g, r):
        return compressed_mean(g[0], r[0], "pod", bits=8, group=32)

    fm = shard_map_compat(f, mesh, in_specs=(P("pod"), P("pod")),
                          out_specs=(P(), P()))
    n = 64
    for _ in range(n):
        out, r = fm(g[None], r[None])
        sent = sent + out
    # one 8-bit quantum at the clamped min exponent is 2^-16 ~ 15x the
    # per-step signal; error feedback recovers the mean over many rounds
    np.testing.assert_allclose(np.asarray(sent / n), np.asarray(g),
                               rtol=0.25)
