"""Data pipeline, 8-bit optimizer, checkpoint manager, gradient
compression, sharding rules."""
import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gse import PackedGSETensor, gse_bits_per_value
from repro.data.pipeline import (DataConfig, PrefetchingLoader,
                                 batch_at_step)
from repro.optim.adamw8bit import AdamW8bit, PackedMoment
from repro.checkpoint.manager import CheckpointManager
from repro.distributed.sharding import (ShardingRules, resolve_pspec,
                                        shard_map_compat, strip_axes)


# ---------------- data -----------------------------------------------------

def test_data_deterministic_and_step_pure():
    cfg = DataConfig(vocab=100, seq_len=64, global_batch=4)
    b1 = batch_at_step(cfg, 7)
    b2 = batch_at_step(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = batch_at_step(cfg, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=50, seq_len=32, global_batch=2)
    b = batch_at_step(cfg, 0)
    assert b["tokens"].shape == (2, 32)
    assert b["labels"].shape == (2, 32)
    assert b["loss_mask"].shape == (2, 32)
    assert set(np.unique(b["loss_mask"])) <= {0.0, 1.0}


def test_data_host_striping_partitions_batch():
    cfg = DataConfig(vocab=50, seq_len=32, global_batch=4)
    full = batch_at_step(cfg, 3)["tokens"]
    h0 = batch_at_step(dataclasses.replace(cfg, host_id=0, num_hosts=2),
                       3)["tokens"]
    h1 = batch_at_step(dataclasses.replace(cfg, host_id=1, num_hosts=2),
                       3)["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0, h1]), full)


def test_prefetch_loader_resumes():
    cfg = DataConfig(vocab=50, seq_len=32, global_batch=2, prefetch=2)
    it = PrefetchingLoader(cfg, start_step=5)
    b = next(it)
    it.close()
    np.testing.assert_array_equal(b["tokens"],
                                  batch_at_step(cfg, 5)["tokens"])


# ---------------- optimizer ------------------------------------------------

def test_adamw8bit_converges_quadratic():
    opt = AdamW8bit(lr=0.1, warmup_steps=1)
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}           # d/dw ||w||^2
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_adamw8bit_close_to_fp32_adam():
    """8-bit states track an exact fp32 AdamW on a *fixed* gradient
    sequence (param-dependent grads would make the comparison chaotic)."""
    rng = np.random.default_rng(0)
    gseq = rng.normal(size=(30, 512)).astype(np.float32)
    opt = AdamW8bit(lr=0.05, warmup_steps=1)
    p8 = {"w": jnp.linspace(-1, 1, 512)}
    s8 = opt.init(p8)
    pf = np.linspace(-1, 1, 512)
    m = np.zeros(512)
    v = np.zeros(512)
    for t in range(1, 31):
        g = gseq[t - 1]
        p8, s8 = opt.update({"w": jnp.asarray(g)}, s8, p8)
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh, vh = m / (1 - 0.9 ** t), v / (1 - 0.999 ** t)
        pf = pf - 0.05 * mh / (np.sqrt(vh) + 1e-8)
    err = float(np.max(np.abs(np.asarray(p8["w"]) - pf)))
    # 30 steps x lr 0.05: total movement ~1.5; 8-bit state noise stays
    # a small fraction of it
    assert err < 0.15, err


def test_adamw8bit_state_is_packed():
    """Moments live as PackedGSETensor word streams (b-bit mantissas +
    shared 5-bit exponents), not int8-per-value."""
    opt = AdamW8bit()
    params = {"a": jnp.ones((1000,))}
    st_ = opt.init(params)
    assert isinstance(st_.m["a"], PackedMoment)
    assert st_.m["a"].packed.mantissa_words.dtype == jnp.uint32
    assert st_.m["a"].packed.bits == 8
    assert st_.v["a"].packed.bits == 8
    assert opt.state_nbytes(st_) < 1000 * 4   # far below fp32 moments


def test_adamw8bit_init_matches_quantize_pack_of_zeros():
    """The direct zero-state construction is word-identical to running the
    fused quantize+pack kernel on an all-zero moment."""
    from repro.kernels.ops import gse_quantize_pack
    opt = AdamW8bit(m_bits=5, group=32)
    st_ = opt.init({"a": jnp.ones((300,))})      # pads 300 -> 512
    ref = gse_quantize_pack(jnp.zeros((512,)), 5, 32)
    np.testing.assert_array_equal(
        np.asarray(st_.m["a"].packed.mantissa_words),
        np.asarray(ref.mantissa_words))
    np.testing.assert_array_equal(
        np.asarray(st_.m["a"].packed.exponent_words),
        np.asarray(ref.exponent_words))
    assert st_.m["a"].n == 300
    np.testing.assert_array_equal(np.asarray(st_.m["a"].values()),
                                  np.zeros(300, np.float32))


@pytest.mark.parametrize("bits", [2, 5, 8])
def test_adamw8bit_state_nbytes_analytic_4096(bits):
    """Acceptance: on a (4096, 4096)-param adapter tree the reported state
    footprint matches 2 * (b + 5/32) / 8 bytes/param within 1% (here:
    exactly — padding bytes are excluded by construction)."""
    n = 4096 * 4096
    opt = AdamW8bit(m_bits=bits, v_bits=bits, group=32)
    st_ = opt.init({"w": jnp.zeros((4096, 4096))})
    analytic = 2 * gse_bits_per_value(bits, 32) / 8 * n
    assert abs(opt.state_nbytes(st_) / analytic - 1) < 0.01
    assert opt.state_nbytes(st_) == int(analytic)


def test_adamw8bit_state_nbytes_excludes_padding():
    """Footprint tracks param.size exactly, not the BLOCK-padded
    allocation (n=1000 pads to 1024 internally)."""
    opt = AdamW8bit()                            # b=8, group=32
    st_ = opt.init({"a": jnp.ones((1000,))})
    per_moment = (1000 * 8 + (-(-1000 // 32)) * 5 + 7) // 8
    assert opt.state_nbytes(st_) == 2 * per_moment
    # the padded device allocation is strictly larger
    dev = sum(l.size * 4 for l in jax.tree.leaves((st_.m, st_.v)))
    assert dev > opt.state_nbytes(st_)


def test_adamw8bit_per_moment_bits():
    """b is configurable per-moment; update keeps running and nbytes
    reflects the mixed widths."""
    opt = AdamW8bit(lr=0.01, warmup_steps=1, m_bits=4, v_bits=8)
    params = {"w": jnp.linspace(-1, 1, 128)}
    st_ = opt.init(params)
    assert st_.m["w"].packed.bits == 4 and st_.v["w"].packed.bits == 8
    g = {"w": jnp.ones((128,))}
    params, st_ = opt.update(g, st_, params)
    assert st_.m["w"].packed.bits == 4 and st_.v["w"].packed.bits == 8
    exp = ((128 * 4 + 4 * 5 + 7) // 8) + ((128 * 8 + 4 * 5 + 7) // 8)
    assert opt.state_nbytes(st_) == exp


def test_adamw8bit_warmup_reaches_full_lr_on_time():
    """update advances step before current_lr, so warmup ramps 1/W..W/W:
    the first update uses lr/W (not 2/W) and full LR lands exactly at
    step == warmup_steps (the old code saturated one step early)."""
    opt = AdamW8bit(lr=1.0, warmup_steps=4)
    lrs = [float(opt.current_lr(jnp.int32(s))) for s in (1, 2, 3, 4, 5)]
    np.testing.assert_allclose(lrs, [0.25, 0.5, 0.75, 1.0, 1.0])
    # end-to-end: the metrics lr of the first real update is lr/W
    params = {"w": jnp.ones((8,))}
    st_ = opt.init(params)
    _, st_ = opt.update({"w": jnp.ones((8,))}, st_, params)
    np.testing.assert_allclose(float(opt.current_lr(st_.step)), 0.25)


def test_adamw8bit_packed_state_checkpoint_roundtrip(tmp_path):
    """Optimizer state checkpoints as packed words and restores
    bit-exactly (the training-resume path for packed moments)."""
    opt = AdamW8bit(lr=0.05, warmup_steps=1)
    params = {"w": jnp.linspace(-1, 1, 200)}
    st_ = opt.init(params)
    params, st_ = opt.update({"w": jnp.ones((200,))}, st_, params)
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(1, {"opt": st_})
    got, _, _ = mgr.restore(1, {"opt": st_})
    ropt = got["opt"]
    assert isinstance(ropt.m["w"], PackedMoment)
    assert ropt.m["w"].n == 200
    for a, b in ((ropt.m["w"], st_.m["w"]), (ropt.v["w"], st_.v["w"])):
        np.testing.assert_array_equal(
            np.asarray(a.packed.mantissa_words),
            np.asarray(b.packed.mantissa_words))
        np.testing.assert_array_equal(
            np.asarray(a.packed.exponent_words),
            np.asarray(b.packed.exponent_words))
    assert int(ropt.step) == 1


# ---------------- checkpoint ------------------------------------------------

def test_checkpoint_roundtrip_mixed_dtypes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.int8).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "d": jnp.float32(2.5)}}
    mgr.save(3, tree, metadata={"note": "x"})
    out, meta, step = mgr.restore(3, tree)
    assert step == 3 and meta["note"] == "x"
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["b"]["c"], np.float32), 1.5)


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest() == 4


def test_checkpoint_nf4_tree_roundtrip(tmp_path):
    from repro.core.nf4 import nf4_quantize
    mgr = CheckpointManager(str(tmp_path), keep=1)
    t = nf4_quantize(jax.random.normal(jax.random.PRNGKey(0), (64, 64)))
    tree = {"w": t}
    mgr.save(1, tree)
    out, _, _ = mgr.restore(1, tree)
    np.testing.assert_array_equal(np.asarray(out["w"].codes),
                                  np.asarray(t.codes))
    np.testing.assert_allclose(np.asarray(out["w"].dequantize(jnp.float32)),
                               np.asarray(t.dequantize(jnp.float32)))


# ---------------- sharding rules -------------------------------------------

class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_resolve_pspec_divisibility_guard():
    mesh = _FakeMesh({"data": 4, "model": 8})
    rules = ShardingRules.single_pod()
    # 12 heads on 8-way model -> replicate; 64 ff divisible -> shard
    spec = resolve_pspec((2, 12, 64), (None, "heads", "ff"), mesh, rules)
    assert spec[1] is None and spec[2] == "model"


def test_resolve_pspec_no_axis_reuse():
    mesh = _FakeMesh({"data": 4, "model": 8})
    rules = ShardingRules(batch=("data",), ff="model", heads="model")
    spec = resolve_pspec((8, 16, 64), ("batch", "heads", "ff"), mesh, rules)
    # heads claims model first; ff must then replicate
    assert spec[1] == "model" and spec[2] is None


def test_opt_state_pspecs_shard_word_streams():
    """ZeRO-1 placement of the packed moments: the flat word-planar
    mantissa streams shard over the opt_state rule axis (when the word
    count divides); exponent words and the step scalar replicate."""
    from repro.distributed.params import opt_state_pspecs
    opt = AdamW8bit()
    st_ = opt.init({"w": jnp.ones((1024,))})     # 256 mantissa words
    mesh = _FakeMesh({"data": 4, "model": 8})
    from jax.sharding import PartitionSpec as P
    specs = opt_state_pspecs(st_, mesh, ShardingRules.single_pod())
    assert specs.m["w"].packed.mantissa_words == P(("data",))
    assert specs.m["w"].packed.exponent_words == P()
    assert specs.step == P()
    # non-divisible stream -> divisibility guard replicates
    st2 = opt.init({"w": jnp.ones((96,))})       # 256-pad -> 64 words
    mesh3 = _FakeMesh({"data": 3})
    specs2 = opt_state_pspecs(st2, mesh3, ShardingRules.single_pod())
    assert specs2.m["w"].packed.mantissa_words in (P(), P(None))


def test_strip_axes():
    rules = ShardingRules()            # batch=("pod","data")
    s = strip_axes(rules, "pod")
    assert s.batch == "data"
    s2 = strip_axes(rules, "pod", "data")
    assert s2.batch is None


# ---------------- gradient compression --------------------------------------

def test_compressed_mean_single_shard_semantics():
    """shard_map over a size-1 axis: compressed mean == quantized value and
    the residual captures exactly the quantization error."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compressed_mean
    mesh = jax.make_mesh((1,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 1e-3
    r0 = jnp.zeros((256,))

    def f(g, r):
        return compressed_mean(g[0], r[0], "pod", bits=8, group=32)

    out, res = shard_map_compat(
        f, mesh, in_specs=(P("pod"), P("pod")),
        out_specs=(P(), P()))(g[None], r0[None])
    np.testing.assert_allclose(np.asarray(out + res), np.asarray(g),
                               atol=1e-7)


def test_compression_wire_exact_at_power_of_two_amax():
    """Regression for the jnp.ceil(jnp.log2)/jnp.exp2 shared-exponent math
    this file's wire format used to rely on: at group amax exactly
    qmax * 2^e, XLA's log2 approximation could flip the shared exponent by
    one ulp depending on fusion context, changing the on-wire words
    between the jitted train step and any eager reference. With
    ceil_log2/exp2_int the wire is bit-identical under jit fusion."""
    import numpy as np

    from repro.core.gse import pack_mantissas
    from repro.distributed.compression import (_group_quantize_shared,
                                               _local_exponent)

    bits, group, qmax = 8, 32, 127

    def wire(g):
        """The exact producer compressed_mean puts on the DCI (pmax over a
        size-1 axis is the identity, so e_star == e_local)."""
        e = _local_exponent(g, bits, group)
        m = _group_quantize_shared(g, e, bits, group)
        return e, pack_mantissas(m.reshape(-1), bits)

    for e_true in (-12, -3, 0, 7):
        # every group's amax is exactly qmax * 2^e (exact in fp32):
        # the adversarial point where an inexact log2 flips the exponent
        amax = np.float32(qmax) * np.float32(2.0) ** e_true
        g = np.zeros((8, group), np.float32)
        g[:, 0] = amax
        g[:, 1] = amax / 2
        g = jnp.asarray(g.reshape(-1))

        e_eager, w_eager = wire(g)
        e_jit, w_jit = jax.jit(wire)(g)
        # the exponent is exactly e_true (ceil_log2(2^e) == e), eagerly
        # and under jit -- and the packed words match bit for bit
        np.testing.assert_array_equal(np.asarray(e_eager),
                                      np.full(8, e_true, np.int8))
        np.testing.assert_array_equal(np.asarray(e_jit),
                                      np.asarray(e_eager))
        np.testing.assert_array_equal(np.asarray(w_jit),
                                      np.asarray(w_eager))
        # the amax element quantizes to exactly qmax (no clip, no off-by-
        # one scale), its half to qmax/2 rounded to nearest-even
        m = np.asarray(_group_quantize_shared(g, e_jit, bits, group))
        assert (m[:, 0] == qmax).all()
        assert (m[:, 1] == round(qmax / 2)).all()


def test_error_feedback_reduces_bias():
    """Repeatedly syncing the same gradient with error feedback: the
    accumulated transmitted mass approaches the true value."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compressed_mean
    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.full((32,), 1e-6)     # deep below one 8-bit step of its group
    r = jnp.zeros((32,))
    sent = jnp.zeros((32,))

    def f(g, r):
        return compressed_mean(g[0], r[0], "pod", bits=8, group=32)

    fm = shard_map_compat(f, mesh, in_specs=(P("pod"), P("pod")),
                          out_specs=(P(), P()))
    n = 64
    for _ in range(n):
        out, r = fm(g[None], r[None])
        sent = sent + out
    # one 8-bit quantum at the clamped min exponent is 2^-16 ~ 15x the
    # per-step signal; error feedback recovers the mean over many rounds
    np.testing.assert_allclose(np.asarray(sent / n), np.asarray(g),
                               rtol=0.25)
