"""Pallas kernels vs pure-jnp oracles: shape/dtype/bits sweeps
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import MaskInfo, direct_attention

SHAPES = [(64, 128), (128, 512), (256, 1024), (32, 64)]
BITS = [5, 6, 8]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("bits", BITS)
def test_gse_quant_kernel_exact(shape, bits):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 0.4
    m1, e1 = ops.gse_quantize(x, bits, 32, bm=32, bk=64)
    m2, e2 = ref.gse_quantize_ref(x, bits, 32)
    assert bool(jnp.all(m1 == m2)) and bool(jnp.all(e1 == e2))


@pytest.mark.parametrize("dtype", DTYPES)
def test_gse_quant_kernel_dtypes(dtype):
    x = (jax.random.normal(jax.random.PRNGKey(1), (64, 128)) * 0.2
         ).astype(dtype)
    m1, e1 = ops.gse_quantize(x, 6, 32, bm=32, bk=64)
    m2, e2 = ref.gse_quantize_ref(x, 6, 32)
    assert bool(jnp.all(m1 == m2)) and bool(jnp.all(e1 == e2))


@pytest.mark.parametrize("mkn", [(64, 128, 32), (128, 512, 64),
                                 (32, 256, 128)])
@pytest.mark.parametrize("bits", [5, 8])
def test_gse_matmul_kernel_exact(mkn, bits):
    m, k, n = mkn
    a = jax.random.normal(jax.random.PRNGKey(2), (m, k)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(3), (n, k)) * 0.3
    am, ae = ops.gse_quantize(a, bits, 32, bm=32, bk=64)
    bm_, be = ops.gse_quantize(b, bits, 32, bm=32, bk=64)
    y1 = ops.gse_matmul(am, ae, bm_, be, 32, bm=32, bn=32, bk=64)
    y2 = ref.gse_matmul_ref(am, ae, bm_, be, 32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=0,
                               atol=0)


@pytest.mark.parametrize("mkn", [(32, 256, 96), (64, 512, 128),
                                 (96, 128, 32), (16, 1024, 64)])
@pytest.mark.parametrize("bits", [5, 6, 8])
def test_gse_matmul_parity_packed_and_unpacked(mkn, bits):
    """Both kernel paths (int8 and fused packed-dequant) are bit-exact vs
    the value-space oracle ``gse_matmul_reference`` on non-square M/K/N —
    the ordered-accumulation contract, not an allclose."""
    from repro.core.gse import gse_matmul_reference, gse_pack, gse_quantize
    m, k, n = mkn
    a = jax.random.normal(jax.random.PRNGKey(10 + bits + m), (m, k)) * 0.3
    b = jax.random.normal(jax.random.PRNGKey(20 + bits + n), (n, k)) * 0.3
    ta = gse_quantize(a, bits, 32)
    tb = gse_quantize(b, bits, 32)
    pb = gse_pack(tb)
    ref_out = np.asarray(gse_matmul_reference(ta, tb))
    bm, bn = min(32, m), min(32, n)
    for bk in (64, k):
        y_u = ops.gse_matmul(ta.mantissa, ta.exponent, tb.mantissa,
                             tb.exponent, 32, bm=bm, bn=bn, bk=bk)
        y_p = ops.gse_matmul_packed(ta.mantissa, ta.exponent,
                                    pb.mantissa_words, tb.exponent, bits,
                                    32, bm=bm, bn=bn, bk=bk)
        np.testing.assert_array_equal(np.asarray(y_u), ref_out)
        np.testing.assert_array_equal(np.asarray(y_p), ref_out)


def _packed_operand(seed, shape, bits, group=32):
    """Quantize along the last axis and return (words, int8 exps)."""
    from repro.core.gse import gse_pack, gse_quantize, unpack_exponents
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * 0.4
    p = gse_pack(gse_quantize(x, bits, group))
    return (p.mantissa_words,
            unpack_exponents(p.exponent_words, p.exponent_shape))


@pytest.mark.parametrize("bits", [(4, 4), (6, 8), (8, 5)])
@pytest.mark.parametrize("mnk", [(32, 128, 64), (64, 256, 128)])
def test_gse_matmul_packed_nt_vs_oracle(bits, mnk):
    """dX-shaped transposed-contraction packed matmul (both operands
    packed, tile-local dequant) is bit-exact vs the ref oracle at matching
    contraction tiling — incl. mixed a/b bit-widths."""
    from repro.kernels.gse_matmul import gse_matmul_packed_nt_pallas
    ab, bb = bits
    m, n, k = mnk
    aw, ae = _packed_operand(1 + ab, (m, n), ab)      # dY along N
    bw, be = _packed_operand(2 + bb, (n, k), bb)      # W^T along K
    y1 = gse_matmul_packed_nt_pallas(aw, ae, bw, be, ab, bb, 32, 32,
                                     bm=min(32, m), bn=64, bk=64)
    y2 = ref.gse_matmul_packed_nt_ref(aw, ae, bw, be, ab, bb, 32, bn=64)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


@pytest.mark.parametrize("bits", [(4, 4), (6, 8)])
@pytest.mark.parametrize("mnk", [(128, 64, 128), (256, 128, 64)])
def test_gse_matmul_packed_tn_vs_oracle(bits, mnk):
    """dW-shaped token-contraction packed matmul vs the ref oracle —
    contraction over the shared leading axis of two packed operands."""
    from repro.kernels.gse_matmul import gse_matmul_packed_tn_pallas
    ab, bb = bits
    m, n, k = mnk
    aw, ae = _packed_operand(3 + ab, (m, k), ab)      # X along K
    bw, be = _packed_operand(4 + bb, (m, n), bb)      # dY along N
    y1 = gse_matmul_packed_tn_pallas(aw, ae, bw, be, ab, bb, 32, 32,
                                     bm=64, bn=min(64, n), bk=64)
    y2 = ref.gse_matmul_packed_tn_ref(aw, ae, bw, be, ab, bb, 32, bm=64)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


@pytest.mark.parametrize("bits", [4, 8])
def test_transposed_packed_matmul_int32_shift_parity(bits):
    """The bitcast-int32 shift fallback is bit-identical on both new
    backward kernels (transposed-contraction and token-contraction)."""
    from repro.kernels.gse_matmul import (gse_matmul_packed_nt_pallas,
                                          gse_matmul_packed_tn_pallas)
    aw, ae = _packed_operand(11, (32, 128), bits)
    bw, be = _packed_operand(12, (128, 64), bits)
    kw = dict(bm=32, bn=64, bk=64)
    y1 = gse_matmul_packed_nt_pallas(aw, ae, bw, be, bits, bits, 32, 32,
                                     **kw)
    y2 = gse_matmul_packed_nt_pallas(aw, ae, bw, be, bits, bits, 32, 32,
                                     int32_shifts=True, **kw)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    xw, xe = _packed_operand(13, (128, 64), bits)
    dw, de = _packed_operand(14, (128, 96), bits)
    y1 = gse_matmul_packed_tn_pallas(xw, xe, dw, de, bits, bits, 32, 32,
                                     bm=64, bn=32, bk=32)
    y2 = gse_matmul_packed_tn_pallas(xw, xe, dw, de, bits, bits, 32, 32,
                                     bm=64, bn=32, bk=32, int32_shifts=True)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


@pytest.mark.parametrize("shape", [(64, 128), (32, 256), (8, 64)])
@pytest.mark.parametrize("bits", [2, 5, 6, 8])
def test_gse_quant_pack_kernel_exact(shape, bits):
    """Fused quantize+pack emits the identical uint32 words and exponents
    as the two-dispatch quantize-then-pack oracle."""
    x = jax.random.normal(jax.random.PRNGKey(bits), shape) * 0.4
    w1, e1 = ops.gse_quant_pack(x, bits, 32, bm=32, bk=64)
    w2, e2 = ref.gse_quant_pack_ref(x, bits, 32)
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


@settings(max_examples=30, deadline=None)
@given(bits=st.integers(2, 8), scale=st.floats(1e-5, 1e4),
       seed=st.integers(0, 2 ** 16))
def test_property_gse_quant_pack_bit_exact(bits, scale, seed):
    """Acceptance sweep: fused kernel vs oracle, bit-exact across
    b in [2, 8] and magnitudes spanning the exponent range."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 128)) * scale
    w1, e1 = ops.gse_quant_pack(x, bits, 32, bm=8, bk=64)
    w2, e2 = ref.gse_quant_pack_ref(x, bits, 32)
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


@pytest.mark.parametrize("shape,group", [((1792,), 32), ((2, 3, 64), 32),
                                         ((4, 5, 16, 8), 8), ((3, 40), 20)])
def test_gse_quantize_pack_dispatcher_matches_jnp(shape, group):
    """The shape-polymorphic entry point (kernel retiling for 32-aligned
    last axes, jnp fallback for ragged) reproduces gse_pack(gse_quantize)
    word-for-word on every layout."""
    from repro.core.gse import gse_pack, gse_quantize
    x = jax.random.normal(jax.random.PRNGKey(7), shape) * 1.3
    p1 = ops.gse_quantize_pack(x, 6, group)
    p2 = gse_pack(gse_quantize(x, 6, group))
    assert p1.shape == p2.shape and p1.nbytes == p2.nbytes
    np.testing.assert_array_equal(np.asarray(p1.mantissa_words),
                                  np.asarray(p2.mantissa_words))
    np.testing.assert_array_equal(np.asarray(p1.exponent_words),
                                  np.asarray(p2.exponent_words))


def test_gse_quant_pack_roundtrips_through_unpack():
    """words from the fused kernel feed the existing unpack kernel and
    come back as the gse_quantize mantissas (kernel-to-kernel contract)."""
    from repro.core.gse import gse_quantize
    x = jax.random.normal(jax.random.PRNGKey(11), (64, 256)) * 0.5
    words, _ = ops.gse_quant_pack(x, 6, 32, bm=32, bk=64)
    m = ops.gse_unpack(words, 6, bm=32, bk=64)
    np.testing.assert_array_equal(np.asarray(m),
                                  np.asarray(gse_quantize(x, 6, 32).mantissa))


@pytest.mark.parametrize("bits", [2, 5, 6, 8])
def test_gse_unpack_kernel_exact(bits):
    from repro.core.gse import gse_pack, gse_quantize
    x = jax.random.normal(jax.random.PRNGKey(bits), (64, 256)) * 0.5
    t = gse_quantize(x, bits, 32)
    words = gse_pack(t).mantissa_words
    m1 = ops.gse_unpack(words, bits, bm=32, bk=64)
    m2 = ref.gse_unpack_ref(words, bits)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(t.mantissa))
    np.testing.assert_array_equal(np.asarray(m2), np.asarray(t.mantissa))


def test_gse_linear_packed_matches_unpacked():
    from repro.core.gse import gse_pack, gse_quantize
    x = jax.random.normal(jax.random.PRNGKey(40), (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(41), (128, 256)) * 0.1
    pw = gse_pack(gse_quantize(w, 6, 32))
    y1 = ops.gse_linear_packed(x, pw, bm=32, bn=32, bk=64)
    y2 = ops.gse_linear(x, w, 6, 32)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_gse_linear_end_to_end_vs_fakequant():
    from repro.core.gse import gse_fake_quant
    x = jax.random.normal(jax.random.PRNGKey(4), (64, 256))
    w = jax.random.normal(jax.random.PRNGKey(5), (128, 256)) * 0.1
    y1 = ops.gse_linear(x, w, 6, 32)
    y2 = gse_fake_quant(x, 6, 32) @ gse_fake_quant(w, 6, 32).T
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6,
                               atol=1e-5)


@pytest.mark.parametrize("shape", [(64, 128), (128, 512)])
def test_nf4_dequant_kernel_exact(shape, ):
    from repro.core.nf4 import nf4_quantize, BLOCK
    m, k = shape
    w = jax.random.normal(jax.random.PRNGKey(6), (m, k)) * 0.05
    t = nf4_quantize(w)
    # reconstruct first-level absmax from double-quantized fields
    qs = np.asarray(t.qscale, np.float32)
    pad = (-qs.shape[0]) % 256
    qsp = np.pad(qs, (0, pad)).reshape(-1, 256)
    absmax = (qsp * np.asarray(t.qscale_scale)[:, None]
              ).reshape(-1)[:qs.shape[0]] + float(t.qscale_mean)
    codes = t.codes.reshape(m, k)
    d1 = ops.nf4_dequant(codes, jnp.asarray(absmax), bm=32, bk=64)
    d2 = ref.nf4_dequant_ref(codes, jnp.asarray(absmax))
    assert bool(jnp.all(d1 == d2))


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 32)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_kernel_vs_oracle(causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    bh, t, d = 4, 128, 64
    q = (jax.random.normal(ks[0], (bh, t, d))).astype(dtype)
    k = (jax.random.normal(ks[1], (bh, t, d))).astype(dtype)
    v = (jax.random.normal(ks[2], (bh, t, d))).astype(dtype)
    o1 = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                bq=32, bk=32)
    o2 = direct_attention(q[:, :, None, :], k[:, :, None, :],
                          v[:, :, None, :],
                          MaskInfo(causal=causal, window=window))[:, :, 0]
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-6
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=tol)


@pytest.mark.parametrize("bits", [2, 5, 8])
def test_int32_shift_fallback_bit_identical(bits):
    """The uint32->int32 bitcast shift path (Mosaic targets without u32
    shifts) emits bit-identical words/mantissas/products across all three
    packed kernels — pack, unpack, and fused packed-dequant matmul."""
    from repro.core.gse import gse_pack, gse_quantize
    from repro.kernels.gse_quant_pack import gse_quant_pack_pallas
    from repro.kernels.gse_unpack import gse_unpack_pallas
    from repro.kernels.gse_matmul import gse_matmul_packed_pallas
    x = jax.random.normal(jax.random.PRNGKey(50 + bits), (64, 256)) * 0.4
    w1, e1 = gse_quant_pack_pallas(x, bits, 32, bm=32, bk=64)
    w2, e2 = gse_quant_pack_pallas(x, bits, 32, bm=32, bk=64,
                                   int32_shifts=True)
    np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))
    m1 = gse_unpack_pallas(w1, bits, bm=32, bk=64)
    m2 = gse_unpack_pallas(w1, bits, bm=32, bk=64, int32_shifts=True)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    a = gse_quantize(jax.random.normal(jax.random.PRNGKey(51), (32, 256)),
                     bits, 32)
    tb = gse_quantize(x, bits, 32)
    pb = gse_pack(tb)
    y1 = gse_matmul_packed_pallas(a.mantissa, a.exponent, pb.mantissa_words,
                                  tb.exponent, bits, 32, bm=32, bn=32,
                                  bk=64)
    y2 = gse_matmul_packed_pallas(a.mantissa, a.exponent, pb.mantissa_words,
                                  tb.exponent, bits, 32, bm=32, bn=32,
                                  bk=64, int32_shifts=True)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_int32_shift_fallback_host_pack_unpack():
    """Host-side jnp pack/unpack under int32 shifts roundtrips every value
    of every field width (exhaustive over the 5-bit exponent range and
    8-bit mantissa range)."""
    from repro.core.gse import pack_unsigned, unpack_unsigned
    for nbits in (1, 5, 8, 16):
        u = jnp.arange(2 ** min(nbits, 11), dtype=jnp.uint32) % (2 ** nbits)
        w_u = pack_unsigned(u, nbits)
        w_i = pack_unsigned(u, nbits, int32_shifts=True)
        np.testing.assert_array_equal(np.asarray(w_u), np.asarray(w_i))
        back = unpack_unsigned(w_i, nbits, u.shape[0], int32_shifts=True)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(u))


def test_flash_kernel_block_shape_sweep():
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (2, 256, 32))
    k = jax.random.normal(ks[1], (2, 256, 32))
    v = jax.random.normal(ks[2], (2, 256, 32))
    base = None
    for bq, bk in [(32, 32), (64, 128), (128, 64), (256, 256)]:
        o = flash_attention_pallas(q, k, v, causal=True, bq=bq, bk=bk)
        if base is None:
            base = o
        np.testing.assert_allclose(np.asarray(o), np.asarray(base),
                                   atol=2e-5)
