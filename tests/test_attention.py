"""Flash (chunked online-softmax) attention vs direct: fwd + custom VJP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (MaskInfo, direct_attention,
                                    flash_attention, flash_attention_ref)

INFOS = [MaskInfo(causal=True), MaskInfo(causal=False),
         MaskInfo(causal=True, window=32),
         MaskInfo(causal=True, window=32, is_global=jnp.array(True))]


def _qkv(key, b=2, t=128, h=8, kv=4, d=32):
    ks = jax.random.split(key, 4)
    return (jax.random.normal(ks[0], (b, t, h, d)),
            jax.random.normal(ks[1], (b, t, kv, d)),
            jax.random.normal(ks[2], (b, t, kv, d)),
            jax.random.normal(ks[3], (b, t, h, d)))


@pytest.mark.parametrize("idx", range(len(INFOS)))
def test_forward_matches_direct(idx):
    info = INFOS[idx]
    q, k, v, _ = _qkv(jax.random.PRNGKey(idx))
    o1 = flash_attention_ref(q, k, v, info, q_chunk=16, k_chunk=32)
    o2 = direct_attention(q, k, v, info)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-6)


@pytest.mark.parametrize("idx", range(len(INFOS)))
def test_custom_vjp_matches_direct_grads(idx):
    info = INFOS[idx]
    q, k, v, do = _qkv(jax.random.PRNGKey(10 + idx))
    f = lambda q, k, v: jnp.sum(flash_attention(q, k, v, info, 16, 32) * do)
    g = lambda q, k, v: jnp.sum(direct_attention(q, k, v, info) * do)
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_decode_offset_semantics():
    """q_offset shifts causality: one query at abs position 100 sees the
    first 101 cache slots."""
    q, k, v, _ = _qkv(jax.random.PRNGKey(20), t=128)
    info = MaskInfo(q_offset=jnp.asarray(100), causal=True)
    o = direct_attention(q[:, :16], k, v, info)
    # identical to slicing the cache at 101 + bidir attention over it
    o_ref = direct_attention(q[:, :16][:, :1], k[:, :101], v[:, :101],
                             MaskInfo(causal=False))
    np.testing.assert_allclose(np.asarray(o[:, :1]), np.asarray(o_ref),
                               atol=3e-6)


def test_mha_vs_gqa_consistency():
    """GQA with kv == h equals plain MHA math."""
    q, k, v, _ = _qkv(jax.random.PRNGKey(21), h=4, kv=4)
    o1 = direct_attention(q, k, v, MaskInfo(causal=True))
    # manual per-head attention
    outs = []
    for h in range(4):
        s = jnp.einsum("btd,bsd->bts", q[:, :, h], k[:, :, h]) * (32 ** -0.5)
        m = jnp.tril(jnp.ones((128, 128), bool))
        s = jnp.where(m[None], s, -1e30)
        p = jax.nn.softmax(s, -1)
        outs.append(jnp.einsum("bts,bsd->btd", p, v[:, :, h]))
    o2 = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-6)


def test_dispatch_chooses_flash_for_large():
    from repro.models import attention as A
    q, k, v, _ = _qkv(jax.random.PRNGKey(22), t=2048, h=2, kv=2, d=16)
    o = A.attention(q, k, v, MaskInfo(causal=True), q_chunk=512,
                    k_chunk=1024)
    o2 = direct_attention(q, k, v, MaskInfo(causal=True))
    np.testing.assert_allclose(np.asarray(o), np.asarray(o2), atol=3e-6)
