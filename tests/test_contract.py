"""Trace-auditor tests (repro.analysis.contract).

Three layers: the invariant engines on handcrafted HLO (unit), seeded
contract violations the auditor must flag (the "provably fails" half of
the acceptance criteria), and a green run of the real checks on the
tier-1 config (the cheap ones inline; the full matrix is the CI step).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import contract
from repro.kernels import ops

# ------------------------------------------------- engine unit tests ------

_HLO_INT = """\
HloModule m

ENTRY %main (a: s8[16,32], b: s8[64,32]) -> s32[16,64] {
  %a = s8[16,32]{1,0} parameter(0)
  %b = s8[64,32]{1,0} parameter(1)
  ROOT %dot.0 = s32[16,64]{1,0} dot(s8[16,32]{1,0} %a, s8[64,32]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={1}
}
"""

_HLO_FP = """\
HloModule m

ENTRY %main (a: f32[16,32], b: f32[32,64]) -> f32[16,64] {
  %a = f32[16,32]{1,0} parameter(0)
  %b = f32[32,64]{1,0} parameter(1)
  ROOT %dot.0 = f32[16,64]{1,0} dot(f32[16,32]{1,0} %a, f32[32,64]{1,0} %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""

_HLO_BUFFERS = """\
HloModule m

%fused_computation (p0: u32[8,16]) -> f32[64,96] {
  %p0 = u32[8,16]{1,0} parameter(0)
  ROOT %cvt = f32[64,96]{1,0} convert(u32[8,16]{1,0} %p0)
}

%while_body (p: f32[64,96]) -> f32[64,96] {
  %p = f32[64,96]{1,0} parameter(0)
  ROOT %add = f32[64,96]{1,0} add(f32[64,96]{1,0} %p, f32[64,96]{1,0} %p)
}

ENTRY %main (w: u32[8,16]) -> f32[64,96] {
  %w = u32[8,16]{1,0} parameter(0)
  ROOT %fus = f32[64,96]{1,0} fusion(u32[8,16]{1,0} %w), kind=kLoop, calls=%fused_computation
}
"""


def test_dot_census_classifies_by_operand_and_result_dtype():
    c = contract.dot_census(_HLO_INT)
    assert len(c["int"]) == 1 and c["fp"] == []
    assert c["int"][0]["operand_dtypes"] == ["s8", "s8"]
    c = contract.dot_census(_HLO_FP)
    assert len(c["fp"]) == 1 and c["int"] == []


def test_audit_int_route_flags_fp_and_missing_int_dots():
    assert contract.audit_int_route(_HLO_INT) == []
    v = contract.audit_int_route(_HLO_FP)
    assert any("fp dot" in s for s in v)
    assert any("no integer dot" in s for s in v)
    # the PV-GEMM exemption keys on the result minor dim
    assert contract.audit_int_route(_HLO_FP, fp_ok_minor_dim=64) == [
        "no integer dot found on an int-MAC route"]


def test_fp_buffer_scan_excludes_fusion_bodies_not_while_bodies():
    # the f32[64,96] inside %fused_computation is VMEM (fusion internals);
    # the same shape in %while_body and ENTRY materializes
    hits = contract.fp_buffer_scan(_HLO_BUFFERS, dims=[(64, 96)])
    comps = sorted({h["computation"] for h in hits})
    assert comps == ["main", "while_body"]
    # flat-size matching catches reshape disguises
    hits = contract.fp_buffer_scan(_HLO_BUFFERS, flat_sizes={64 * 96})
    assert hits


_HLO_WORDS = """\
HloModule m

%fused_computation (p0: u32[6,64,2,8]) -> u32[6,64,2,8] {
  %p0 = u32[6,64,2,8]{3,2,1,0} parameter(0)
  ROOT %sh = u32[6,64,2,8]{3,2,1,0} shift-right-logical(u32[6,64,2,8]{3,2,1,0} %p0, u32[6,64,2,8]{3,2,1,0} %p0)
}

ENTRY %main (a: u32[6,64,2,8]) -> u32[6,64,2,4] {
  %a = u32[6,64,2,8]{3,2,1,0} parameter(0)
  %view = u32[6,64,2,4]{3,2,1,0} slice(u32[6,64,2,8]{3,2,1,0} %a), slice={[0:6], [0:64], [0:2], [0:4]}
  %flat = u32[6,64,8]{2,1,0} reshape(u32[6,64,2,4]{3,2,1,0} %view)
  %fus = u32[6,64,2,8]{3,2,1,0} fusion(u32[6,64,2,8]{3,2,1,0} %a), kind=kLoop, calls=%fused_computation
  ROOT %repack = u32[6,64,2,4]{3,2,1,0} and(u32[6,64,2,4]{3,2,1,0} %view, u32[6,64,2,4]{3,2,1,0} %view)
}
"""


def test_u32_word_scan_flags_arithmetic_not_views():
    """The zero-copy engine: a cache-shaped u32 result from *arithmetic*
    in a materializing computation is a re-pack; `slice`/`reshape` (the
    zero-copy ops themselves), fusion internals, and the stored-width
    pass-through are not."""
    dims = [(6, 64, 2, 4), (6, 64, 2, 8)]
    hits = contract.u32_word_compute_scan(_HLO_WORDS, dims)
    assert len(hits) == 1 and "%repack" in hits[0]["line"]
    v = contract.audit_view_zero_copy(_HLO_WORDS, dims)
    assert len(v) == 1 and "re-pack" in v[0]
    # nothing cache-shaped in sight -> clean
    assert contract.u32_word_compute_scan(_HLO_WORDS, [(9, 9)]) == []


def test_check_plane_prefix_view_green():
    """The real gate on the real programs: the narrowed planar read and
    the mixed-width paged serve program audit clean."""
    r = contract.check_plane_prefix_view()
    assert r["ok"], r["violations"]


# --------------------------------------------- seeded violations ----------

def test_seeded_fp_dot_on_int_route_is_flagged():
    """Replace the integer score GEMM with a dequant + fp matmul: the
    int-dot-route audit must fail on the lowered program."""
    q = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    qm, qe = ops.gse_quantize(q, 8, 32)
    km, ke = ops.gse_quantize(k, 8, 32)

    def broken(qm, qe, km, ke):
        from repro.core.gse import exp2_int
        qf = qm.astype(jnp.float32).reshape(8, 2, 32) \
            * exp2_int(qe)[..., None]
        kf = km.astype(jnp.float32).reshape(64, 2, 32) \
            * exp2_int(ke)[..., None]
        return jnp.einsum("rgc,sgc->rs", qf, kf)     # fp MACs: violation

    hlo = jax.jit(broken).lower(qm, qe, km, ke).compile().as_text()
    v = contract.audit_int_route(hlo)
    assert any("fp dot" in s or "no integer dot" in s for s in v)


def test_seeded_full_width_unpacked_leaf_is_flagged():
    """Dequantizing the whole packed KV cache materializes an fp buffer of
    the full unpacked shape: the one-tile-unpacked audit must fail."""
    b, s, kv, d = 1, 128, 2, 32
    k = jax.random.normal(jax.random.PRNGKey(0), (b, s, kv, d))
    kw, ke = ops.quant_pack_kv_rows(k, 8)

    def broken(kw, ke):
        return ops.dequant_kv_rows(kw, ke, d, jnp.float32)

    hlo = jax.jit(broken).lower(kw, ke).compile().as_text()
    v = contract.audit_no_unpacked_fp(hlo, [(b, s, kv, d)],
                                      {b * s * kv * d})
    assert v, "full-cache dequant must be seen as a materialized fp buffer"


def test_seeded_transcendental_wire_math_is_flagged():
    """The pre-fix compression.py recipe — jnp.ceil(jnp.log2(...)) /
    jnp.exp2 shared-exponent math and a raw int8 gather — must trip both
    wire invariants at the jaxpr level."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map_compat

    mesh = jax.make_mesh((1,), ("pod",))

    def broken(g):
        e = jnp.ceil(jnp.log2(jnp.maximum(jnp.abs(g), 1e-9)))
        m = jnp.round(g * jnp.exp2(-e)).astype(jnp.int8)
        m_all = jax.lax.all_gather(m, "pod")         # s8 wire: violation
        return jnp.sum(m_all.astype(jnp.float32), axis=0) * jnp.exp2(e)

    fm = shard_map_compat(lambda g: broken(g[0]), mesh,
                          in_specs=(P("pod"),), out_specs=P())
    prims = contract.jaxpr_census(
        jax.make_jaxpr(fm)(jnp.ones((1, 64)) * 0.25))
    v = contract.audit_wire(prims)
    assert any("not packed unsigned words" in s for s in v)
    assert any("transcendental" in s for s in v)


def test_wire_audit_green_on_real_packed_compressed_mean():
    prims = contract.jaxpr_census(contract.trace_wire_jaxpr(packed=True))
    assert contract.audit_wire(prims) == []
    # the legacy unpacked exchange is s8 on the wire — the audit sees it
    prims = contract.jaxpr_census(contract.trace_wire_jaxpr(packed=False))
    assert any("not packed unsigned words" in s
               for s in contract.audit_wire(prims))


# --------------------------------------------------- green checks ---------

def test_check_score_tile_green():
    r = contract.check_score_tile()
    assert r["ok"], r["violations"]


def test_check_guard_coverage_green():
    r = contract.check_guard_coverage()
    assert r["ok"], r["violations"]
    assert "int_mac Pallas entry" in r["detail"]


@pytest.mark.slow
def test_full_contract_audit_green():
    """The CI gate end to end: every check on the tier-1 config matrix."""
    report = contract.run_checks()
    assert report["schema"] == contract.REPORT_SCHEMA
    bad = [r for r in report["checks"] if not r["ok"]]
    assert report["ok"], bad
