"""Launch layer: cell plans, input specs, HLO walker, roofline math, and a
multi-device lower+compile smoke (subprocess with 8 fake devices)."""
import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import pytest

from repro.analysis import hlo_walk
from repro.analysis.roofline import Roofline
from repro.launch import cells as C
from repro.configs import all_arch_names


def test_cell_grid_is_40():
    assert len(list(C.all_cells())) == 40


def test_long_500k_skips_match_design():
    skipped = [a for a in all_arch_names()
               if C.cell_plan(a, "long_500k").skip]
    assert set(skipped) == set(all_arch_names()) - {"mamba2_2_7b",
                                                    "hymba_1_5b"}


def test_accum_respects_dp():
    class M:
        shape = {"pod": 2, "data": 16, "model": 16}
    p = C.cell_plan("llava_next_34b", "train_4k", M())
    assert p.accum * 32 <= 256 and p.accum >= 1


def test_input_specs_modes():
    cfg = C.arch_cfg("granite_3_2b")
    tr = C.input_specs(cfg, "train_4k")
    assert tr["tokens"].shape == (256, 4096)
    pf = C.input_specs(cfg, "prefill_32k")
    assert pf["tokens"].shape == (32, 32768)
    dc = C.input_specs(cfg, "decode_32k")
    assert dc["tokens"].shape == (128, 1)
    vcfg = C.arch_cfg("llava_next_34b")
    vtr = C.input_specs(vcfg, "train_4k")
    assert vtr["inputs_embeds"].shape == (256, 4096, 7168)
    wcfg = C.arch_cfg("whisper_small")
    wtr = C.input_specs(wcfg, "train_4k")
    assert wtr["frames"].shape == (256, 1536, 768)


def test_hlo_walker_counts_while_trips():
    hlo = textwrap.dedent("""\
    HloModule test
    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %g0 = s32[] get-tuple-element(%p), index=0
      %g1 = f32[8,8] get-tuple-element(%p), index=1
      %d = f32[8,8] dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,8] all-reduce(%d), to_apply=%add
      ROOT %t = (s32[], f32[8,8]) tuple(%g0, %ar)
    }
    %cond (p2: (s32[], f32[8,8])) -> pred[] {
      %p2 = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }
    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }
    ENTRY %main (x: f32[8,8]) -> f32[8,8] {
      %x = f32[8,8] parameter(0)
      %c = s32[] constant(0)
      %tp = (s32[], f32[8,8]) tuple(%c, %x)
      %w = (s32[], f32[8,8]) while(%tp), condition=%cond, body=%body
      ROOT %o = f32[8,8] get-tuple-element(%w), index=1
    }
    """)
    res = hlo_walk.walk(hlo)
    # dot: 2*8*8*8 = 1024 flops x 5 trips
    assert res.flops == 1024 * 5
    assert res.while_trips == [5]
    assert res.collective_counts["all-reduce"] == 5
    assert res.collective_bytes["all-reduce"] == 8 * 8 * 4 * 5


def test_roofline_terms_and_dominance():
    r = Roofline(flops=197e12, hbm_bytes=819e9, collective_bytes=0.0,
                 chips=256, model_flops=197e12 * 256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(1.0)
    assert r.dominant in ("compute", "memory")
    assert r.roofline_fraction == pytest.approx(1.0)


def test_int8_fraction_raises_compute_roof():
    r8 = Roofline(flops=1e15, hbm_bytes=0, collective_bytes=0, chips=1,
                  int8_fraction=1.0)
    rb = Roofline(flops=1e15, hbm_bytes=0, collective_bytes=0, chips=1,
                  int8_fraction=0.0)
    assert r8.compute_s == pytest.approx(rb.compute_s / 2)


SMOKE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax
from repro.launch import mesh as MESH
MESH.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
    (2, 2, 2) if multi_pod else (2, 4),
    ("pod", "data", "model") if multi_pod else ("data", "model"))
from repro.launch import dryrun as DR
DR.make_production_mesh = MESH.make_production_mesh
import repro.launch.cells as C
import dataclasses, json
# shrink the cell to a reduced config for the smoke
from repro.configs import reduced_config
C.arch_cfg = lambda arch, shape=None: reduced_config(arch)
C.SHAPES = {"train_4k": dict(seq_len=64, global_batch=8, mode="train"),
            "decode_32k": dict(seq_len=128, global_batch=8, mode="decode")}
res = DR.lower_cell("granite_3_2b", "train_4k", False, verbose=False)
res2 = DR.lower_cell("granite_3_2b", "decode_32k", True, verbose=False)
print(json.dumps({"a": res["status"], "b": res2["status"]}))
"""


@pytest.mark.slow
def test_multidevice_lower_compile_subprocess():
    """8 fake devices, reduced config: the full dryrun path (shardings,
    lower, compile, roofline extraction) must succeed for single+multi."""
    out = subprocess.run([sys.executable, "-c", SMOKE], cwd="/root/repo",
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    d = json.loads(line)
    assert d == {"a": "ok", "b": "ok"}
