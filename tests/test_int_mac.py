"""Integer-MAC modes of the packed kernels (ISSUE 6).

Two tiers, two contracts:

* exact tier — the packed-attention score GEMM contracts over head_dim,
  the row-planar grouping axis, so int8 MACs + the rank-1 ``2^(eq+ek)``
  rescale are **bit-exact** vs the fp32 score path's per-group math
  (array_equal, not allclose);
* bounded tier — ``gse_matmul_packed_nt/tn`` contract over a non-grouping
  axis, so mantissas realign to a tile-shared exponent (low bits shift
  out): parity vs the fp32 kernels holds within the documented worst-case
  bound (``ref.int_realign_bound``), and the mode is gated behind
  ``QuantPolicy.int_mac`` (default off) with a static overflow guard.

Plus the observability satellites: ``last_qcd_route`` for all three QCD
GEMMs and the unified env tri-state knob table.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.gse import gse_fake_quant
from repro.kernels import ops, ref
from repro.kernels.flash_attention_packed import (
    flash_attention_packed_jnp, flash_attention_packed_pallas,
    quant_pack_kv_rows, unpack_kv_row_mantissas)
from repro.kernels.gse_matmul import (INT32_ACC_MAX, check_int_mac_depth,
                                      gse_matmul_packed_nt_pallas,
                                      gse_matmul_packed_tn_pallas,
                                      gse_score_tile, int_mac_max_depth)
from repro.kernels.gse_quant import quantize_tile
from repro.kernels.gse_quant_pack import gse_quant_pack_pallas
from repro.core.qcd import quantized_matmul

BITS = [4, 6, 8]


def _scaled(shape, seed, spread):
    """Rows with adversarial power-of-two scale spreads (per-row exponents
    span ±spread) — the worst case for tile-shared-exponent realignment."""
    rng = np.random.default_rng(seed)
    vals = rng.standard_normal(shape).astype(np.float32)
    scales = 2.0 ** rng.integers(-spread, spread + 1, (shape[0], 1))
    return jnp.asarray(vals * scales, jnp.float32)


# ------------------------- exact tier: score GEMM -------------------------


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("d", [64, 128])
def test_score_tile_matches_grouped_fp32_oracle(bits, d):
    """int8 MAC + rank-1 rescale == per-group fp32 GEMM, bit for bit."""
    q = _scaled((48, d), seed=bits, spread=12)
    k = _scaled((96, d), seed=bits + 10, spread=12)
    kw, ke = quant_pack_kv_rows(k, bits, 32)
    oracle = ref.gse_score_int_ref(q, kw, ke, d)
    qm, qe = quantize_tile(q, bits, 32)
    tile = gse_score_tile(qm.astype(jnp.int8), qe.astype(jnp.int8),
                          unpack_kv_row_mantissas(kw, d), ke, group=32)
    np.testing.assert_array_equal(np.asarray(oracle), np.asarray(tile))


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("tail", [False, True])
def test_attention_int_kernel_equals_fallback(bits, tail):
    """The Pallas kernel and the jnp fallback run the identical integer
    score sequence — array_equal across routes, GQA and the decode tail."""
    b, t, h, kv, d, s = 1, 16, 4, 2, 64, 64
    q = _scaled((b * t * h, d), 1, 6).reshape(b, t, h, d)
    k = _scaled((b * s * kv, d), 2, 6).reshape(b, s, kv, d)
    v = jnp.asarray(np.random.default_rng(3).standard_normal(
        (b, s, kv, d)), jnp.float32)
    kw, ke = quant_pack_kv_rows(k, bits, 32)
    vw, ve = quant_pack_kv_rows(v, bits, 32)
    tails = {}
    if tail:
        rng = np.random.default_rng(4)
        tails = dict(
            k_tail=jnp.asarray(rng.standard_normal((b, 2, kv, d)),
                               jnp.float32),
            v_tail=jnp.asarray(rng.standard_normal((b, 2, kv, d)),
                               jnp.float32))
    kwargs = dict(causal=True, q_offset=s - t, **tails)
    o_jnp = flash_attention_packed_jnp(q, kw, ke, vw, ve, k_chunk=32,
                                       int_mac=True, **kwargs)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * kv, x.shape[1], -1)
    qf = q.reshape(b, t, kv, h // kv, d).transpose(0, 2, 3, 1, 4).reshape(
        b * kv, h // kv, t, d)
    ktails = ({k2: fold(v2) for k2, v2 in tails.items()} if tail else {})
    o_krn = flash_attention_packed_pallas(
        qf, fold(kw), fold(ke), fold(vw), fold(ve), causal=True,
        q_offset=s - t, bq=16, bk=32, interpret=True, int_mac=True,
        **ktails)
    o_krn = o_krn.reshape(b, kv, h // kv, t, d).transpose(
        0, 3, 1, 2, 4).reshape(b, t, h, d)
    np.testing.assert_array_equal(np.asarray(o_jnp), np.asarray(o_krn))


@pytest.mark.parametrize("bits", BITS)
def test_attention_int_equals_fp32_single_group(bits):
    """d=32 (one group) with pre-fake-quantized q: the int path's only
    lossy step (q quantization) is idempotent, so int == fp32 bitwise —
    the within-group exactness argument observed end to end."""
    b, t, h, kv, d, s = 1, 8, 2, 2, 32, 32
    rng = np.random.default_rng(7)
    q = gse_fake_quant(jnp.asarray(rng.standard_normal((b, t, h, d)),
                                   jnp.float32), bits, d)
    k = _scaled((b * s * kv, d), 8, 8).reshape(b, s, kv, d)
    v = jnp.asarray(rng.standard_normal((b, s, kv, d)), jnp.float32)
    kw, ke = quant_pack_kv_rows(k, bits, 32)
    vw, ve = quant_pack_kv_rows(v, bits, 32)
    o_fp = flash_attention_packed_jnp(q, kw, ke, vw, ve, causal=True,
                                      q_offset=s - t, k_chunk=32)
    o_int = flash_attention_packed_jnp(q, kw, ke, vw, ve, causal=True,
                                       q_offset=s - t, k_chunk=32,
                                       int_mac=True)
    np.testing.assert_array_equal(np.asarray(o_fp), np.asarray(o_int))


@given(bits=st.sampled_from(BITS), spread=st.integers(0, 14))
@settings(max_examples=10, deadline=None)
def test_score_property_adversarial_spreads(bits, spread):
    d = 64
    q = _scaled((16, d), seed=spread, spread=spread)
    k = _scaled((32, d), seed=spread + 99, spread=spread)
    kw, ke = quant_pack_kv_rows(k, bits, 32)
    qm, qe = quantize_tile(q, bits, 32)
    tile = gse_score_tile(qm.astype(jnp.int8), qe.astype(jnp.int8),
                          unpack_kv_row_mantissas(kw, d), ke, group=32)
    np.testing.assert_array_equal(
        np.asarray(ref.gse_score_int_ref(q, kw, ke, d)), np.asarray(tile))


# ----------------- bounded tier: realigned nt/tn matmuls ------------------


def _packed_pair(m, n, bits, seed, spread):
    a = _scaled((m, n), seed, spread)
    return gse_quant_pack_pallas(a, bits=bits, group=32)


@pytest.mark.parametrize("bits", BITS)
def test_nt_int_matches_replay_ref_and_bound(bits):
    aw, ae = _packed_pair(32, 256, bits, bits, 12)
    bw, be = _packed_pair(256, 64, bits, bits + 1, 12)
    out = gse_matmul_packed_nt_pallas(aw, ae, bw, be, a_bits=bits,
                                      b_bits=bits, bn=128, int_mac=True,
                                      interpret=True)
    # bit-exact vs the independent floor-division realignment replay
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(ref.gse_matmul_packed_nt_int_ref(aw, ae, bw, be, bits,
                                                    bits, bn=128)))
    # within the documented worst-case bound vs the fp32 kernel (oracle)
    fp = gse_matmul_packed_nt_pallas(aw, ae, bw, be, a_bits=bits,
                                     b_bits=bits, bn=128, interpret=True)
    bound = ref.int_realign_bound(ae, be, bits, bits, tile=128, kind="nt")
    assert (np.abs(np.asarray(out) - np.asarray(fp))
            <= np.asarray(bound)).all()


@pytest.mark.parametrize("bits", BITS)
def test_tn_int_matches_replay_ref_and_bound(bits):
    aw, ae = _packed_pair(256, 64, bits, bits + 2, 12)
    bw, be = _packed_pair(256, 96, bits, bits + 3, 12)
    out = gse_matmul_packed_tn_pallas(aw, ae, bw, be, a_bits=bits,
                                      b_bits=bits, bm=128, int_mac=True,
                                      interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out),
        np.asarray(ref.gse_matmul_packed_tn_int_ref(aw, ae, bw, be, bits,
                                                    bits, bm=128)))
    fp = gse_matmul_packed_tn_pallas(aw, ae, bw, be, a_bits=bits,
                                     b_bits=bits, bm=128, interpret=True)
    bound = ref.int_realign_bound(ae, be, bits, bits, tile=128, kind="tn")
    assert (np.abs(np.asarray(out) - np.asarray(fp))
            <= np.asarray(bound)).all()


@given(bits=st.sampled_from(BITS), spread=st.integers(0, 14))
@settings(max_examples=8, deadline=None)
def test_nt_property_adversarial_spreads(bits, spread):
    aw, ae = _packed_pair(32, 128, bits, spread, spread)
    bw, be = _packed_pair(128, 32, bits, spread + 50, spread)
    out = gse_matmul_packed_nt_pallas(aw, ae, bw, be, a_bits=bits,
                                      b_bits=bits, bn=64, int_mac=True,
                                      interpret=True)
    fp = gse_matmul_packed_nt_pallas(aw, ae, bw, be, a_bits=bits,
                                     b_bits=bits, bn=64, interpret=True)
    bound = ref.int_realign_bound(ae, be, bits, bits, tile=64, kind="nt")
    assert (np.abs(np.asarray(out) - np.asarray(fp))
            <= np.asarray(bound)).all()


def test_fp32_path_untouched_by_int_flag_default():
    """int_mac default off: the fp32 kernels stay the oracle (identical
    output with the flag absent vs explicitly False)."""
    aw, ae = _packed_pair(32, 128, 6, 5, 8)
    bw, be = _packed_pair(128, 64, 6, 6, 8)
    o1 = gse_matmul_packed_nt_pallas(aw, ae, bw, be, a_bits=6, b_bits=6,
                                     bn=64, interpret=True)
    o2 = gse_matmul_packed_nt_pallas(aw, ae, bw, be, a_bits=6, b_bits=6,
                                     bn=64, int_mac=False, interpret=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


# -------------------------- static overflow guard -------------------------


def test_overflow_guard_rejects_wrapping_depth():
    assert int_mac_max_depth(8, 8) == INT32_ACC_MAX // (127 * 127)
    check_int_mac_depth(int_mac_max_depth(8, 8), 8, 8)   # at the limit: ok
    with pytest.raises(ValueError, match="overflow"):
        check_int_mac_depth(2 ** 18, 8, 8)


def test_overflow_guard_fires_at_trace_time(monkeypatch):
    """The wrapper rejects a wrapping tile config before tracing the kernel
    (monkeypatched accumulator cap so a test-sized bn trips it)."""
    from repro.kernels import gse_matmul as gm
    aw, ae = _packed_pair(32, 128, 8, 1, 4)
    bw, be = _packed_pair(128, 32, 8, 2, 4)
    monkeypatch.setattr(gm, "INT32_ACC_MAX", 64 * 127 * 127 - 1)
    with pytest.raises(ValueError, match="overflow"):
        gse_matmul_packed_nt_pallas(aw, ae, bw, be, a_bits=8, b_bits=8,
                                    bn=128, int_mac=True, interpret=True)
    monkeypatch.setattr(gm, "INT32_ACC_MAX", 128 * 127 * 127)
    gse_matmul_packed_nt_pallas(aw, ae, bw, be, a_bits=8, b_bits=8,
                                bn=128, int_mac=True, interpret=True)


# --------------------- QCD routing observability --------------------------


def _qcd_grads(x, w, int_mac=False):
    y, vjp = jax.vjp(lambda a, b: quantized_matmul(
        a, b, 6, 6, 6, 32, True, None, int_mac), x, w)
    dx, dw = vjp(jnp.ones_like(y))
    return y, dx, dw


def test_last_qcd_route_observable_for_all_gemms(monkeypatch):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((128, 96)), jnp.float32)

    monkeypatch.setenv("REPRO_QCD_PACKED_KERNELS", "0")
    _qcd_grads(x, w)
    for gemm in ("y", "dx", "dw"):
        route, reason = ops.last_qcd_route(gemm)
        assert route == "fallback" and "qcd_packed_kernels() off" in reason
    assert set(ops.last_qcd_route()) == {"y", "dx", "dw"}

    monkeypatch.setenv("REPRO_QCD_PACKED_KERNELS", "1")
    g_fp = _qcd_grads(x, w)
    assert ops.last_qcd_route("y") == (
        "kernel", "packed operands on the kernel path [int8 MXU group MACs]")
    for gemm in ("dx", "dw"):
        route, reason = ops.last_qcd_route(gemm)
        assert route == "kernel" and "fp32 tile MACs" in reason

    # int-MAC mode annotates the route reason and changes only the backward
    g_int = _qcd_grads(x, w, int_mac=True)
    for gemm in ("dx", "dw"):
        route, reason = ops.last_qcd_route(gemm)
        assert route == "kernel" and "int32 realigned MACs" in reason
    np.testing.assert_array_equal(np.asarray(g_fp[0]), np.asarray(g_int[0]))

    # REPRO_INT_MAC=0 overrides the argument back to the fp32 kernels
    monkeypatch.setenv("REPRO_INT_MAC", "0")
    g_off = _qcd_grads(x, w, int_mac=True)
    assert "fp32 tile MACs" in ops.last_qcd_route("dx")[1]
    for a, b in zip(g_fp, g_off):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_qcd_route_reports_unpacked_operands():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    # fake-quant path: no packed residuals -> fallback with operand reason
    y, vjp = jax.vjp(lambda a, b: quantized_matmul(a, b, 6, 6, 6, 32,
                                                   False), x, w)
    vjp(jnp.ones_like(y))
    # the fake-quant backward never dispatches through ops.qcd_matmul_*,
    # so the last recorded routes are whatever ran before; the packed
    # fwd/bwd with raw (unquantized) dY is the observable case:
    yq = ops.qcd_matmul_dx(jnp.ones((4, 32)), w.T, compute_dtype=jnp.float32)
    route, reason = ops.last_qcd_route("dx")
    assert route == "fallback" and "not packed GSE" in reason
    assert yq.shape == (4, 64)


# --------------------- env tri-state knob table ---------------------------


def test_env_tristate_knob_table(monkeypatch):
    """Every kernel knob speaks the same 1/0/auto vocabulary — including
    REPRO_QCD_F32_OUT, formerly the one bespoke truthy reader."""
    for name, reader in ops.ENV_TRISTATE_KNOBS.items():
        for val, want in [("1", True), ("true", True), ("on", True),
                          ("0", False), ("false", False), ("off", False)]:
            monkeypatch.setenv(name, val)
            assert reader() is want, (name, val)
        monkeypatch.delenv(name)
    # auto/unset on CPU: every knob defers to a False default
    assert jax.default_backend() != "tpu"
    for name, reader in ops.ENV_TRISTATE_KNOBS.items():
        assert reader() is False, name
        monkeypatch.setenv(name, "auto")
        assert reader() is False, name
        monkeypatch.delenv(name)


def test_qcd_f32_out_unified_vocabulary(monkeypatch):
    # a stray value is "auto" (default off) now, not implicitly truthy
    monkeypatch.setenv("REPRO_QCD_F32_OUT", "yes-please")
    assert ops.qcd_f32_out() is False
    monkeypatch.setenv("REPRO_QCD_F32_OUT", "1")
    assert ops.qcd_f32_out() is True


def test_int_mac_env_override(monkeypatch):
    monkeypatch.delenv("REPRO_INT_MAC", raising=False)
    assert ops.resolve_int_mac(True) is True
    assert ops.resolve_int_mac(False) is False
    monkeypatch.setenv("REPRO_INT_MAC", "1")
    assert ops.resolve_int_mac(False) is True
    monkeypatch.setenv("REPRO_INT_MAC", "0")
    assert ops.resolve_int_mac(True) is False
