"""Packed QCD backward residuals (paper Sec. 2.3 on the real storage
substrate): bit-identical A/B parity vs the fake-quant simulation, packed
residual leaves in the vjp, the remat save-names policy, and the
QuantPolicy knobs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Real hypothesis when installed; deterministic reduced sweep otherwise
# (keeps collection green in bare environments -- see _hypothesis_compat).
from _hypothesis_compat import given, settings, st

from repro.core.gse import gse_dequantize_in, gse_fake_quant, gse_quantize
from repro.core.policy import QuantPolicy
from repro.core.qcd import quantized_matmul
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.train.step import accumulate_grads, lm_loss

BITS = [4, 6, 8]
# (k, group): 128/32 is the aligned per-row layout; 40/32 degrades to the
# ragged flat-stream pack with effective group 20 (largest divisor <= 32)
K_GROUP = [(128, 32), (40, 32)]

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=256, vocab=64,
                  vocab_pad_multiple=32, remat=True)
POL_FAKE = QuantPolicy.gsq(8, rank=8)
POL_PACK = dataclasses.replace(POL_FAKE, residuals_packed=True)


def _pair(m, k, n, seed=0, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, k)).astype(dtype)
    w = (jax.random.normal(jax.random.PRNGKey(seed + 1), (k, n)) * 0.1
         ).astype(dtype)
    return x, w


def _grads(x, w, ct, bits, group, packed, residual_bits=None):
    y, vjp = jax.vjp(
        lambda a, b: quantized_matmul(a, b, bits, bits, bits, group,
                                      packed, residual_bits), x, w)
    dx, dw = vjp(ct)
    return y, dx, dw


def _assert_all_equal(a, b):
    for u, v in zip(a, b):
        np.testing.assert_array_equal(np.asarray(u, np.float32),
                                      np.asarray(v, np.float32))


# ---------------- bit-identical A/B parity vs fake-quant ------------------

@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("k,group", K_GROUP)
def test_packed_parity_bit_identical(bits, k, group):
    """Forward output AND both backward GEMM outputs are bit-identical to
    the fake-quant simulation at matching bits — aligned and ragged-K
    (flat-stream) residual layouts alike."""
    x, w = _pair(32, k, 64, seed=bits)
    ct = jax.random.normal(jax.random.PRNGKey(9), (32, 64))
    _assert_all_equal(_grads(x, w, ct, bits, group, False),
                      _grads(x, w, ct, bits, group, True))


@pytest.mark.parametrize("bits", [4, 8])
def test_packed_parity_bf16(bits):
    """Same parity in the training dtype (bf16 activations/weights)."""
    x, w = _pair(64, 128, 32, seed=bits, dtype=jnp.bfloat16)
    ct = jax.random.normal(jax.random.PRNGKey(3), (64, 32)
                           ).astype(jnp.bfloat16)
    _assert_all_equal(_grads(x, w, ct, bits, 32, False),
                      _grads(x, w, ct, bits, 32, True))


@settings(max_examples=20, deadline=None)
@given(bits=st.sampled_from(BITS), seed=st.integers(0, 2 ** 16),
       scale=st.floats(1e-3, 1e2))
def test_property_backward_parity(bits, seed, scale):
    """Property sweep: packed-residual vjp vs the fake-quant oracle across
    magnitudes spanning the exponent range."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 64)) * scale
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (64, 32)) * 0.1
    ct = jax.random.normal(jax.random.PRNGKey(seed + 2), (16, 32))
    _assert_all_equal(_grads(x, w, ct, bits, 32, False),
                      _grads(x, w, ct, bits, 32, True))


def test_f32_out_env_parity(monkeypatch):
    monkeypatch.setenv("REPRO_QCD_F32_OUT", "1")
    x, w = _pair(16, 128, 32, seed=7)
    ct = jnp.ones((16, 32))
    _assert_all_equal(_grads(x, w, ct, 6, 32, False),
                      _grads(x, w, ct, 6, 32, True))


def test_dequantize_in_matches_fake_quant():
    """The dtype-matched dequant of the working/packed forms reproduces
    gse_fake_quant bit-for-bit — the identity the whole parity rests on."""
    for dtype in (jnp.float32, jnp.bfloat16):
        x = (jax.random.normal(jax.random.PRNGKey(0), (32, 128)) * 3.0
             ).astype(dtype)
        t = gse_quantize(x, 6, 32)
        np.testing.assert_array_equal(
            np.asarray(gse_dequantize_in(t, dtype), np.float32),
            np.asarray(gse_fake_quant(x, 6, 32), np.float32))


# ---------------- residual wire format --------------------------------- --

def test_vjp_residuals_are_packed_words_only():
    """With residuals_packed=True the saved-for-backward set contains NO
    full-precision tensors: every residual leaf is a uint32 word stream
    (the zero-length dtype token is the only float leaf, and it is empty)."""
    x, w = _pair(32, 128, 64)
    _, vjp = jax.vjp(
        lambda a, b: quantized_matmul(a, b, 6, 6, 6, 32, True), x, w)
    leaves = jax.tree_util.tree_leaves(vjp)
    float_leaves = [l for l in leaves
                    if jnp.issubdtype(l.dtype, jnp.floating) and l.size]
    assert not float_leaves, [(l.shape, l.dtype) for l in float_leaves]
    words = [l for l in leaves if l.dtype == jnp.uint32]
    assert words, "expected packed word-stream residuals"
    # x residual: (32, 128) at 6 bits -> (32, 128/32*6) words
    assert any(l.shape == (32, 24) for l in words)


def test_vjp_residual_bytes_match_bits_per_value():
    """Residual words scale with b: the (M, K) activation residual holds
    K/32*b words per row — the b + 5/group bits/value claim as shapes."""
    x, w = _pair(32, 128, 64)
    for bits in (4, 8):
        _, vjp = jax.vjp(lambda a, b: quantized_matmul(
            a, b, bits, bits, bits, 32, True), x, w)
        words = [l for l in jax.tree_util.tree_leaves(vjp)
                 if l.dtype == jnp.uint32]
        assert any(l.shape == (32, 128 // 32 * bits) for l in words)


def test_residual_bits_knob():
    """residual_bits stores the residuals at a lower width than the
    forward operands: forward output is unchanged (still computed at the
    operand bits), grads stay finite/aligned but are no longer
    bit-identical, and the word streams shrink."""
    x, w = _pair(64, 128, 32, seed=11)
    ct = jax.random.normal(jax.random.PRNGKey(12), (64, 32))
    y8, dx8, dw8 = _grads(x, w, ct, 8, 32, True)
    y4, dx4, dw4 = _grads(x, w, ct, 8, 32, True, residual_bits=4)
    np.testing.assert_array_equal(np.asarray(y8), np.asarray(y4))
    assert bool(jnp.all(jnp.isfinite(dx4))) and bool(
        jnp.all(jnp.isfinite(dw4)))
    cos = float(jnp.sum(dw4 * dw8) /
                (jnp.linalg.norm(dw4) * jnp.linalg.norm(dw8)))
    assert cos > 0.95, cos
    _, vjp4 = jax.vjp(lambda a, b: quantized_matmul(
        a, b, 8, 8, 8, 32, True, 4), x, w)
    words4 = sum(l.size for l in jax.tree_util.tree_leaves(vjp4)
                 if l.dtype == jnp.uint32)
    _, vjp8 = jax.vjp(lambda a, b: quantized_matmul(
        a, b, 8, 8, 8, 32, True), x, w)
    words8 = sum(l.size for l in jax.tree_util.tree_leaves(vjp8)
                 if l.dtype == jnp.uint32)
    assert words4 < words8


def test_partial_quant_falls_back_to_legacy():
    """a_bits=None ablations keep the legacy full-width residual path even
    when residuals_packed is requested (documented degradation)."""
    x, w = _pair(16, 64, 32)
    y0 = quantized_matmul(x, w, None, None, None, 32, True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(x @ w),
                               rtol=2e-6, atol=2e-6)


# ---------------- kernel-route dispatch (interpret mode) ------------------

def test_forced_kernel_route_close_to_fallback(monkeypatch):
    """REPRO_QCD_PACKED_KERNELS=1 sends fwd/dX/dW through the Pallas
    kernels (interpret on CPU). Accumulation differs (fp32 ordered tiles vs
    one XLA GEMM) so parity is allclose here, not array_equal."""
    x, w = _pair(64, 128, 64, seed=21)
    ct = jax.random.normal(jax.random.PRNGKey(22), (64, 64))
    ref = _grads(x, w, ct, 6, 32, True)
    monkeypatch.setenv("REPRO_QCD_PACKED_KERNELS", "1")
    ker = _grads(x, w, ct, 6, 32, True)
    for a, b in zip(ref, ker):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


# ---------------- model integration: remat policy + sharding --------------

def _batch(b=4, t=32, seed=1):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, t), 4, 64)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1),
            "loss_mask": jnp.ones((b, t), jnp.float32)}


def test_train_grads_bit_identical_and_residuals_packed():
    """Acceptance: on the tier-1-style config with remat active, a full
    loss+grad step under residuals_packed=True is bit-identical to the
    fake-quant policy, and the saved-for-backward set contains the packed
    qcd word streams (uint32, stacked per layer) with no full-precision
    QCD residual leaves (nothing activation-residual-sized in float)."""
    fz, tr = M.init_model(jax.random.PRNGKey(0), CFG, POL_FAKE)
    batch = _batch()
    l0, a0, g0 = accumulate_grads(tr, fz, batch, CFG, POL_FAKE, 1)
    l1, a1, g1 = accumulate_grads(tr, fz, batch, CFG, POL_PACK, 1)
    assert float(l0) == float(l1)
    assert float(a0["tokens"]) == float(a1["tokens"])
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    _, vjp = jax.vjp(lambda t: lm_loss(t, fz, batch, CFG, POL_PACK)[0], tr)
    leaves = jax.tree_util.tree_leaves(vjp)
    words = [l for l in leaves if l.dtype == jnp.uint32]
    assert words, "remat must save the packed qcd_xq/qcd_wq streams"
    # stacked (L, ...) word streams from the scanned layers
    assert any(l.ndim >= 2 and l.shape[0] == CFG.n_layers for l in words)
    # no float leaf as large as the smallest per-GEMM activation residual
    # (B*T, d_ff) — layer-boundary carries (L, B, T, d_model) are smaller
    # by construction in this config
    res_size = 4 * 32 * CFG.d_ff
    big = [l.shape for l in leaves
           if jnp.issubdtype(l.dtype, jnp.floating) and l.size >= res_size]
    assert not big, big


def test_layers_grad_flow_with_remat_policy():
    """Grad flow through models.layers GEMMs under an explicit
    jax.checkpoint with the packed-residual save-names policy: finite and
    bit-identical to the legacy full-remat fake-quant baseline."""
    from repro.models import layers as L
    fz, tr = L.mlp_init(jax.random.PRNGKey(0), CFG, POL_FAKE)
    x = (jax.random.normal(jax.random.PRNGKey(1), (4, 32, CFG.d_model))
         ).astype(jnp.bfloat16)

    def make_loss(pol):
        body = jax.checkpoint(lambda t, x: L.mlp_apply(fz, t, x, CFG, pol),
                              policy=M._remat_policy(pol))

        def loss(t):
            return jnp.sum(body(t, x).astype(jnp.float32) ** 2)
        return loss

    g0 = jax.grad(make_loss(POL_FAKE))(tr)
    g1 = jax.grad(make_loss(POL_PACK))(tr)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        assert bool(jnp.all(jnp.isfinite(b)))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_quantized_bmm_packed():
    """The vmapped expert GEMMs (MoE path) run the packed residual path
    under vmap — forward and grads bit-identical to fake-quant."""
    from repro.models.layers import _quantized_bmm
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (4, 64, 32)) * 0.1
    y0 = _quantized_bmm(x, w, POL_FAKE)
    y1 = _quantized_bmm(x, w, POL_PACK)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    g0 = jax.grad(lambda a: jnp.sum(_quantized_bmm(a, w, POL_FAKE)))(x)
    g1 = jax.grad(lambda a: jnp.sum(_quantized_bmm(a, w, POL_PACK)))(x)
    np.testing.assert_array_equal(np.asarray(g0), np.asarray(g1))


def test_residual_sharding_rule_resolves():
    """The qcd_residual pspec rule annotates the word-planar residual
    leaves under a mesh without breaking compile (single-device mesh: the
    constraint resolves to replicated via the divisibility guard)."""
    import numpy as onp
    from jax.sharding import Mesh
    from repro.distributed.sharding import ShardingRules, use_sharding
    mesh = Mesh(onp.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    x, w = _pair(32, 128, 64)
    with use_sharding(mesh, ShardingRules.single_pod()):
        y, vjp = jax.vjp(
            lambda a, b: quantized_matmul(a, b, 6, 6, 6, 32, True), x, w)
        dx, dw = vjp(jnp.ones_like(y))
    assert dx.shape == x.shape and dw.shape == w.shape
