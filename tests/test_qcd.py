"""QCD fully-quantized matmul (paper Sec. 2.3): forward/backward fidelity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# Real hypothesis when installed; deterministic reduced sweep otherwise
# (keeps collection green in bare environments -- see _hypothesis_compat).
from _hypothesis_compat import given, settings, st

from repro.core.qcd import effective_group_size, quantized_matmul
from repro.core.gse import gse_fake_quant


@settings(max_examples=40, deadline=None)
@given(k=st.integers(1, 512), g=st.integers(1, 64))
def test_effective_group_size_properties(k, g):
    eff = effective_group_size(k, g)
    assert 1 <= eff <= min(g, k)
    assert k % eff == 0


def test_forward_matches_manual_fakequant():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (16, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 32)) * 0.1
    y = quantized_matmul(x, w, 6, 6, 6, 32)
    yref = gse_fake_quant(x, 6, 32) @ gse_fake_quant(w.T, 6, 32).T
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), rtol=1e-5,
                               atol=1e-5)


def test_backward_quantized_but_aligned():
    """Quantized grads must stay directionally aligned with exact grads."""
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (64, 128))
    w = jax.random.normal(jax.random.PRNGKey(3), (128, 32)) * 0.1

    def fq(w):
        return jnp.sum(quantized_matmul(x, w, 8, 8, 8, 32) ** 2)

    def fe(w):
        return jnp.sum((x @ w) ** 2)

    gq = jax.grad(fq)(w)
    ge = jax.grad(fe)(w)
    cos = float(jnp.sum(gq * ge) /
                (jnp.linalg.norm(gq) * jnp.linalg.norm(ge)))
    assert cos > 0.99


def test_bwd_dx_alignment():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (64, 128))
    w = jax.random.normal(jax.random.PRNGKey(5), (128, 32)) * 0.1
    gq = jax.grad(lambda x: jnp.sum(
        quantized_matmul(x, w, 8, 8, 8, 32) ** 2))(x)
    ge = jax.grad(lambda x: jnp.sum((x @ w) ** 2))(x)
    cos = float(jnp.sum(gq * ge) /
                (jnp.linalg.norm(gq) * jnp.linalg.norm(ge)))
    assert cos > 0.99


def test_bits_none_is_exact():
    x = jax.random.normal(jax.random.PRNGKey(6), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(7), (64, 16))
    y = quantized_matmul(x, w, None, None, None, 32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=2e-6,
                               atol=2e-6)


@settings(max_examples=12, deadline=None)
@given(bits=st.integers(4, 8), seed=st.integers(0, 1000))
def test_property_error_shrinks_with_bits(bits, seed):
    if bits > 6:
        return
    x = jax.random.normal(jax.random.PRNGKey(seed), (32, 64))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (64, 16)) * 0.1
    exact = x @ w
    lo = quantized_matmul(x, w, bits, bits, bits, 32)
    hi = quantized_matmul(x, w, bits + 2, bits + 2, bits + 2, 32)
    el = float(jnp.mean((lo - exact) ** 2))
    eh = float(jnp.mean((hi - exact) ** 2))
    assert eh <= el * 1.05


def test_3d_batched_input():
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, 64))
    w = jax.random.normal(jax.random.PRNGKey(9), (64, 32)) * 0.1
    y = quantized_matmul(x, w, 6, 6, 6, 32)
    assert y.shape == (2, 16, 32)
    g = jax.grad(lambda w: jnp.sum(
        quantized_matmul(x, w, 6, 6, 6, 32)))(w)
    assert g.shape == w.shape and bool(jnp.all(jnp.isfinite(g)))
