"""Packed GSE storage: bit-exact pack/unpack round-trips, realized nbytes,
pytree behavior, and the packed consumers (serve KV cache, checkpoint,
gradient-compression wire format)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Real hypothesis when installed; deterministic reduced sweep otherwise
# (keeps collection green in bare environments -- see _hypothesis_compat).
from _hypothesis_compat import given, settings, st

from repro.core.gse import (EXP_BITS, EXP_MIN, GSETensor, PackedGSETensor,
                            gse_bits_per_value, gse_fake_quant, gse_pack,
                            gse_quantize, gse_unpack, pack_unsigned,
                            qmax_for_bits, unpack_unsigned)

ALL_BITS = list(range(2, 9))
GROUPS = [16, 32, 64]


def _assert_roundtrip_exact(t: GSETensor):
    p = gse_pack(t)
    t2 = gse_unpack(p)
    np.testing.assert_array_equal(np.asarray(t.mantissa),
                                  np.asarray(t2.mantissa))
    np.testing.assert_array_equal(np.asarray(t.exponent),
                                  np.asarray(t2.exponent))
    assert t2.bits == t.bits and t2.group_size == t.group_size


@pytest.mark.parametrize("bits", ALL_BITS)
@pytest.mark.parametrize("group", GROUPS)
def test_roundtrip_bit_exact(bits, group):
    x = jax.random.normal(jax.random.PRNGKey(bits * 7 + group),
                          (6, 192)) * 2.0
    _assert_roundtrip_exact(gse_quantize(x, bits, group))


@pytest.mark.parametrize("bits", [2, 5, 8])
def test_roundtrip_all_zero_groups(bits):
    t = gse_quantize(jnp.zeros((4, 64)), bits, 32)
    assert bool(jnp.all(t.exponent == EXP_MIN))      # the zero-group pin
    _assert_roundtrip_exact(t)


@pytest.mark.parametrize("bits", ALL_BITS)
def test_roundtrip_saturated_qmax(bits):
    """Alternating +/- (qmax * 2^e) values quantize to exactly +/-qmax —
    the extreme mantissa codes must survive offset-binary packing. (amax
    must be qmax times a power of two: the ceil'd group exponent otherwise
    leaves headroom below qmax.)"""
    qmax = qmax_for_bits(bits)
    x = jnp.tile(jnp.array([[1.0, -1.0]]), (4, 32)) * qmax * 4.0
    t = gse_quantize(x, bits, 32)
    assert int(jnp.max(t.mantissa)) == qmax
    assert int(jnp.min(t.mantissa)) == -qmax
    _assert_roundtrip_exact(t)


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(2, 8), group=st.sampled_from(GROUPS),
       scale=st.floats(1e-4, 1e3), seed=st.integers(0, 2 ** 16))
def test_property_roundtrip(bits, group, scale, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 192)) * scale
    _assert_roundtrip_exact(gse_quantize(x, bits, group))


@settings(max_examples=15, deadline=None)
@given(nbits=st.integers(1, 16), k=st.integers(1, 130),
       seed=st.integers(0, 2 ** 16))
def test_property_pack_unsigned_generic(nbits, k, seed):
    """The raw bit-plane packer round-trips any unsigned payload < 2^b."""
    rng = np.random.default_rng(seed)
    u = rng.integers(0, 1 << nbits, size=(3, k), dtype=np.uint32)
    w = pack_unsigned(jnp.asarray(u), nbits)
    back = unpack_unsigned(w, nbits, k)
    np.testing.assert_array_equal(np.asarray(back), u)


@pytest.mark.parametrize("bits", ALL_BITS)
@pytest.mark.parametrize("group", GROUPS)
def test_nbytes_matches_formula(bits, group):
    """nbytes == ceil(n*b + g*5)/8 up to chunk-of-32 word alignment."""
    rows, k = 8, 192
    t = gse_quantize(jnp.ones((rows, k)), bits, group)
    p = gse_pack(t)
    n, g = rows * k, rows * (k // group)
    # exact word-level expectation for the aligned (K % 32 == 0) layout
    expected = 4 * (rows * (-(-k // 32)) * bits + (-(-g // 32)) * EXP_BITS)
    assert p.nbytes == expected
    # and within word-alignment slack of the analytic bit count
    analytic = (n * bits + g * EXP_BITS + 7) // 8
    slack = 4 * 32 * 2                      # one padded chunk per stream
    assert analytic <= p.nbytes <= analytic + slack


def test_nbytes_4096_weight_within_1pct():
    """The acceptance shape: (4096, 4096) @ bits=6 packs to the analytic
    bits/value exactly (device nbytes, not a formula)."""
    w = jax.random.normal(jax.random.PRNGKey(0), (4096, 4096)) * 0.02
    p = gse_pack(gse_quantize(w, 6, 32))
    jax.block_until_ready(p.mantissa_words)
    analytic = gse_bits_per_value(6, 32) / 8 * 4096 ** 2
    assert abs(p.nbytes / analytic - 1) < 0.01
    # device-reported bytes agree with the property
    live = p.mantissa_words.nbytes + p.exponent_words.nbytes
    assert live == p.nbytes


def test_ragged_last_axis_packs_flat():
    """Shapes whose last axis isn't a multiple of 32 (e.g. head_dim 8)
    take the flattened-stream layout: no per-row chunk blowup."""
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 16, 8))
    t = gse_quantize(x, 6, 8)
    p = gse_pack(t)
    _assert_roundtrip_exact(t)
    n = x.size
    analytic = (n * 6 + (n // 8) * EXP_BITS + 7) // 8
    assert p.nbytes <= analytic + 4 * 32 * 2


def test_packed_tensor_is_pytree():
    p = gse_pack(gse_quantize(jnp.ones((4, 64)), 6, 32))
    leaves = jax.tree.leaves(p)
    assert len(leaves) == 2
    p2 = jax.tree.map(lambda x: x, p)
    assert isinstance(p2, PackedGSETensor)
    assert p2.bits == 6 and p2.shape == (4, 64)
    # jit through the pytree boundary
    deq = jax.jit(lambda q: q.dequantize())(p)
    np.testing.assert_array_equal(
        np.asarray(deq), np.asarray(gse_fake_quant(jnp.ones((4, 64)), 6, 32)))


def test_dequantize_matches_unpacked_dequantize():
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 128)) * 0.5
    t = gse_quantize(x, 5, 32)
    np.testing.assert_array_equal(np.asarray(gse_pack(t).dequantize()),
                                  np.asarray(t.dequantize()))


# ---------------- consumers -------------------------------------------------

def test_serve_cache_pack_roundtrip_and_bytes():
    from repro.configs import reduced_config
    from repro.core.policy import QuantPolicy
    from repro.models import model as M
    from repro.serve import engine as E
    fp = QuantPolicy(base_w_nf4=False, a_bits=None, w_bits=None,
                     g_bits=None, adapter_bits=None, fmt="none", rank=8)
    cfg = reduced_config("granite_3_2b")
    fz, tr = M.init_model(jax.random.PRNGKey(0), cfg, fp)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 4, cfg.vocab)
    cache = E.init_decode_cache(cfg, 2, 16)
    _, cache = E.prefill(fz, tr, {"tokens": prompt}, cache, cfg, fp)
    packed = E.pack_decode_cache(cache, bits=6)
    assert isinstance(packed["k"], PackedGSETensor)
    raw = cache["k"].nbytes + cache["v"].nbytes
    # b=6 + shared exponents must land well under the bf16 footprint
    assert E.packed_cache_nbytes(packed) < 0.5 * raw
    back = E.unpack_decode_cache(packed)
    # half-ulp-of-group-scale error bound, like the core roundtrip
    assert float(jnp.max(jnp.abs(
        back["k"].astype(jnp.float32) - cache["k"].astype(jnp.float32)))) < 0.1
    assert bool(jnp.all(back["index"] == cache["index"]))


def test_serve_generate_with_packed_kv_matches_fp_cache():
    """Full-precision policy + 8-bit packed KV: greedy tokens match the
    bf16-cache decode (8-bit KV error is far below argmax margins here)."""
    from repro.configs import reduced_config
    from repro.core.policy import QuantPolicy
    from repro.models import model as M
    from repro.serve import engine as E
    fp = QuantPolicy(base_w_nf4=False, a_bits=None, w_bits=None,
                     g_bits=None, adapter_bits=None, fmt="none", rank=8)
    cfg = reduced_config("granite_3_2b")
    fz, tr = M.init_model(jax.random.PRNGKey(0), cfg, fp)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 4, cfg.vocab)
    out = E.greedy_generate(fz, tr, prompt, cfg, fp, max_new=5)
    outq = E.greedy_generate(fz, tr, prompt, cfg, fp, max_new=5,
                             kv_quant_bits=8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(outq))


def test_checkpoint_roundtrips_packed_leaves(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    d = str(tmp_path / "ckpt")
    mgr = CheckpointManager(d, keep=2)
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 128)) * 0.02
    tree = {"w": w, "packed": gse_pack(gse_quantize(w, 6, 32))}
    mgr.save(1, tree)
    got, _, step = mgr.restore(1, tree)
    assert step == 1
    assert isinstance(got["packed"], PackedGSETensor)
    np.testing.assert_array_equal(np.asarray(got["packed"].mantissa_words),
                                  np.asarray(tree["packed"].mantissa_words))
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(w))


def test_checkpoint_gse_bits_snapshot_smaller_and_dequantizes(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 256)) * 0.02
    tree = {"w": w, "small": jnp.zeros((8,))}
    full = CheckpointManager(str(tmp_path / "full"))
    full.save(1, tree)
    packed = CheckpointManager(str(tmp_path / "packed"))
    packed.save(1, tree, gse_bits=6)
    sz_full = os.path.getsize(os.path.join(full.dir, "step_00000001",
                                           "arrays.npz"))
    sz_packed = os.path.getsize(os.path.join(packed.dir, "step_00000001",
                                             "arrays.npz"))
    assert sz_packed < 0.3 * sz_full            # ~6.16/32 of fp32 + overhead
    got, _, _ = packed.restore(1, tree)
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(gse_fake_quant(w, 6, 32)))
    np.testing.assert_array_equal(np.asarray(got["small"]),
                                  np.zeros((8,), np.float32))


def test_checkpoint_gse_bits_packs_bfloat16_leaves(tmp_path):
    """bf16 (ml_dtypes) leaves — the dtype real model params use — must be
    eligible for packed snapshots (np.issubdtype says bf16 isn't floating;
    the manager must use the jnp check)."""
    from repro.checkpoint.manager import CheckpointManager
    w = (jax.random.normal(jax.random.PRNGKey(0), (256, 256)) * 0.02
         ).astype(jnp.bfloat16)
    mgr = CheckpointManager(str(tmp_path / "bf16"))
    mgr.save(1, {"w": w}, gse_bits=6)
    path = os.path.join(mgr.dir, "step_00000001", "arrays.npz")
    sz = os.path.getsize(path)
    assert sz < 0.5 * w.size * 2                # packed, not raw bf16
    got, _, _ = mgr.restore(1, {"w": w})
    assert got["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(got["w"].astype(jnp.float32))))


@pytest.mark.parametrize("bits", [5, 8])
def test_compression_packed_wire_is_lossless(bits):
    """packed=True changes only the wire encoding: results are bit-equal
    to the legacy int8 all-gather."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compression import compressed_mean
    from repro.distributed.sharding import shard_map_compat
    mesh = jax.make_mesh((1,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (250,)) * 1e-3
    r0 = jnp.zeros((250,))
    outs = {}
    for packed in (True, False):
        def f(gg, rr):
            return compressed_mean(gg[0], rr[0], "pod", bits=bits,
                                   group=32, packed=packed)
        outs[packed] = shard_map_compat(
            f, mesh, in_specs=(P("pod"), P("pod")),
            out_specs=(P(), P()))(g[None], r0[None])
    np.testing.assert_array_equal(np.asarray(outs[True][0]),
                                  np.asarray(outs[False][0]))
    np.testing.assert_array_equal(np.asarray(outs[True][1]),
                                  np.asarray(outs[False][1]))
