"""GSE format: unit + property tests (paper Sec. 2.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# Real hypothesis when installed; deterministic reduced sweep otherwise
# (keeps collection green in bare environments -- see _hypothesis_compat).
from _hypothesis_compat import given, settings, st

from repro.core.gse import (DEFAULT_GROUP, EXP_MAX, EXP_MIN, GSETensor,
                            gse_dequantize, gse_fake_quant,
                            gse_fake_quant_ste, gse_matmul_reference,
                            gse_quantize, gse_bits_per_value,
                            qmax_for_bits, quantization_error)


def test_qmax():
    assert qmax_for_bits(8) == 127
    assert qmax_for_bits(5) == 15
    with pytest.raises(ValueError):
        qmax_for_bits(9)


def test_roundtrip_error_bound():
    """|x - Q(x)| <= 2^(e_g - 1) per element (half-ulp of the group scale)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256)) * 2.0
    t = gse_quantize(x, 6, 32)
    xd = gse_dequantize(t)
    scale = jnp.exp2(t.exponent.astype(jnp.float32))
    bound = jnp.repeat(scale, 32, axis=-1) * 0.5 + 1e-9
    assert bool(jnp.all(jnp.abs(x - xd) <= bound))


def test_exponent_range_and_zero_groups():
    x = jnp.zeros((4, 64))
    t = gse_quantize(x, 6, 32)
    assert bool(jnp.all(t.exponent == EXP_MIN))
    assert bool(jnp.all(t.mantissa == 0))
    big = jnp.full((4, 64), 1e30)
    t2 = gse_quantize(big, 6, 32)
    assert bool(jnp.all(t2.exponent <= EXP_MAX))


def test_fake_quant_equals_quant_dequant():
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 128)) * 0.1
    fq = gse_fake_quant(x, 5, 32)
    qd = gse_dequantize(gse_quantize(x, 5, 32))
    np.testing.assert_allclose(np.asarray(fq), np.asarray(qd), rtol=0,
                               atol=0)


@settings(max_examples=25, deadline=None)
@given(bits=st.integers(2, 8),
       group=st.sampled_from([8, 16, 32, 64]),
       scale=st.floats(1e-4, 1e3),
       seed=st.integers(0, 2 ** 16))
def test_property_idempotent_and_bounded(bits, group, scale, seed):
    """Quantization is idempotent; mantissas respect the b-bit range."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * scale
    t = gse_quantize(x, bits, group)
    qmax = qmax_for_bits(bits)
    assert bool(jnp.all(jnp.abs(t.mantissa.astype(jnp.int32)) <= qmax))
    once = gse_fake_quant(x, bits, group)
    twice = gse_fake_quant(once, bits, group)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               rtol=0, atol=0)


@settings(max_examples=15, deadline=None)
@given(bits=st.integers(4, 8), seed=st.integers(0, 2 ** 16))
def test_property_more_bits_less_error(bits, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (8, 128))
    lo = float(quantization_error(x, bits)["mse"])
    hi = float(quantization_error(x, min(bits + 2, 8))["mse"])
    if bits + 2 <= 8:
        assert hi <= lo * 1.01


def test_matmul_reference_matches_dequant_matmul():
    k = jax.random.PRNGKey(2)
    a = gse_quantize(jax.random.normal(k, (16, 128)), 6, 32)
    b = gse_quantize(jax.random.normal(jax.random.PRNGKey(3), (8, 128)),
                     6, 32)
    y1 = gse_matmul_reference(a, b)
    y2 = a.dequantize() @ b.dequantize().T
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_ste_gradient_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 64))
    g = jax.grad(lambda v: jnp.sum(gse_fake_quant_ste(v, 6, 32) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0 * np.ones_like(g))


def test_stochastic_rounding_unbiased():
    x = jnp.full((1, 32), 0.3)
    t = gse_quantize(x, 8, 32)
    scale = float(jnp.exp2(t.exponent.astype(jnp.float32))[0, 0])
    keys = jax.random.split(jax.random.PRNGKey(5), 200)
    vals = jnp.stack([
        gse_dequantize(gse_quantize(x, 8, 32, stochastic=True, key=k))
        for k in keys])
    assert abs(float(vals.mean()) - 0.3) < scale  # near-unbiased


def test_bits_per_value():
    assert gse_bits_per_value(6, 32) == pytest.approx(6 + 5 / 32)
    assert gse_bits_per_value(8, 64) == pytest.approx(8 + 5 / 64)


def test_packed_bytes():
    t = gse_quantize(jnp.ones((8, 64)), 6, 32)
    # 512 values * 6 bits + 16 exps * 5 bits = 3152 bits -> 394 bytes
    assert t.nbytes_packed() == (8 * 64 * 6 + 16 * 5 + 7) // 8


def test_gse_tensor_is_pytree():
    t = gse_quantize(jnp.ones((4, 32)), 6, 32)
    leaves = jax.tree.leaves(t)
    assert len(leaves) == 2
    t2 = jax.tree.map(lambda x: x, t)
    assert isinstance(t2, GSETensor) and t2.bits == 6
