"""Fused packed-KV flash attention: kernel-vs-oracle bit parity, the
tile-local jnp fallback, the in-place packed decode loop, and the
peak-live-KV-bytes claim (the cache is never materialized unpacked)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.core.gse import gse_fake_quant
from repro.core.policy import QuantPolicy
from repro.core.qcd import effective_group_size
from repro.kernels import ops, ref
from repro.kernels.flash_attention_packed import (
    dequant_kv_rows, flash_attention_packed_jnp,
    flash_attention_packed_pallas, kv_row_bits, kv_row_words,
    quant_pack_kv_rows)
from repro.models import model as M
from repro.models.attention import MaskInfo, direct_attention
from repro.serve import engine as E

FP = QuantPolicy(base_w_nf4=False, a_bits=None, w_bits=None, g_bits=None,
                 adapter_bits=None, fmt="none", rank=8)


def _planes(seed, shape, bits, group=32):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape) * 0.5
    w, e = quant_pack_kv_rows(x, bits, group)
    return x, w, e


# ---------------- row-planar layout ---------------------------------------

@pytest.mark.parametrize("d", [8, 40, 64, 128])
@pytest.mark.parametrize("bits", [4, 8])
def test_quant_pack_rows_roundtrip_exact(d, bits):
    """dequant(quant_pack) == gse_fake_quant at the effective group — the
    row-planar planes carry exactly the GSE values, fused kernel path
    (32-aligned D) and ragged jnp path alike."""
    x, w, e = _planes(d + bits, (3, 5, 2, d), bits)
    assert w.shape[-1] == kv_row_words(d, bits)
    assert kv_row_bits(w.shape[-1], d) == bits
    g = effective_group_size(d, 32)
    np.testing.assert_array_equal(
        np.asarray(dequant_kv_rows(w, e, d)),
        np.asarray(gse_fake_quant(x.astype(jnp.float32), bits, g)))


def test_dequant_rows_matches_ref():
    _, w, e = _planes(0, (4, 16, 2, 64), 6)
    np.testing.assert_array_equal(np.asarray(dequant_kv_rows(w, e, 64)),
                                  np.asarray(ref.packed_kv_dequant_ref(
                                      w, e, 64)))


# ---------------- kernel vs unpack-then-attend oracle ---------------------

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 32)])
@pytest.mark.parametrize("d", [64, 40])
def test_packed_kernel_bit_exact_vs_oracle(bits, causal, window, d):
    """The fused kernel (tile-local dequant in VMEM) is **bit-identical**
    to dequantizing the whole cache and running the dense flash kernel at
    the same tiling — the ordered-accumulation contract, incl. ragged
    head_dim 40 (padded final word chunk)."""
    bh, t, s = 4, 64, 128
    q = jax.random.normal(jax.random.PRNGKey(1), (bh, t, d), jnp.float32)
    _, kw, ke = _planes(2, (bh, s, d), bits)
    _, vw, ve = _planes(3, (bh, s, d), bits)
    o1 = flash_attention_packed_pallas(q, kw, ke, vw, ve, causal=causal,
                                       window=window, bq=32, bk=32)
    o2 = ref.flash_attention_packed_oracle(q, kw, ke, vw, ve,
                                           causal=causal, window=window,
                                           bq=32, bk=32)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


@pytest.mark.parametrize("bits", [4, 8])
def test_packed_kernel_int32_shift_fallback_bit_exact(bits):
    """The bitcast-int32 shift path (Mosaic targets without u32 shifts)
    changes nothing observable."""
    bh, t, s, d = 2, 32, 64, 64
    q = jax.random.normal(jax.random.PRNGKey(4), (bh, t, d), jnp.float32)
    _, kw, ke = _planes(5, (bh, s, d), bits)
    _, vw, ve = _planes(6, (bh, s, d), bits)
    o1 = flash_attention_packed_pallas(q, kw, ke, vw, ve, bq=32, bk=32)
    o2 = flash_attention_packed_pallas(q, kw, ke, vw, ve, bq=32, bk=32,
                                       int32_shifts=True)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_packed_kernel_q_offset_decode_shape():
    """Decode-shaped call: one query row at the end of a longer cache."""
    bh, s, d = 4, 96, 64
    q = jax.random.normal(jax.random.PRNGKey(7), (bh, 1, d), jnp.float32)
    _, kw, ke = _planes(8, (bh, s, d), 8)
    _, vw, ve = _planes(9, (bh, s, d), 8)
    o1 = flash_attention_packed_pallas(q, kw, ke, vw, ve, causal=True,
                                       q_offset=s - 1, bq=1, bk=32)
    o2 = ref.flash_attention_packed_oracle(q, kw, ke, vw, ve, causal=True,
                                           q_offset=s - 1, bq=1, bk=32)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


# ---------------- jnp fallback (the CPU/interpret decode path) ------------

def test_jnp_fallback_bit_exact_vs_kernel():
    """MHA layout at matching tile size: the scan-over-tiles fallback runs
    the identical float sequence as the kernel."""
    bh, t, s, d = 4, 32, 64, 64
    q = jax.random.normal(jax.random.PRNGKey(10), (bh, t, d), jnp.float32)
    _, kw, ke = _planes(11, (bh, s, d), 4)
    _, vw, ve = _planes(12, (bh, s, d), 4)
    ok = flash_attention_packed_pallas(q, kw, ke, vw, ve, causal=True,
                                       bq=t, bk=16)
    oj = flash_attention_packed_jnp(
        q[:, :, None, :], kw[:, :, None, :], ke[:, :, None, :],
        vw[:, :, None, :], ve[:, :, None, :], causal=True, k_chunk=16)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(oj[:, :, 0]))


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 8)])
def test_jnp_fallback_gqa_ragged_vs_direct(causal, window):
    """GQA heads + ragged cache length (pad tile masked) + traced offset
    against the materialized-scores reference."""
    b, t, h, kv, d, s = 2, 8, 4, 2, 64, 24
    q = jax.random.normal(jax.random.PRNGKey(13), (b, t, h, d), jnp.float32)
    _, kw, ke = _planes(14, (b, s, kv, d), 8)
    _, vw, ve = _planes(15, (b, s, kv, d), 8)
    off = jnp.asarray(s - t)                       # traced, like decode
    o = flash_attention_packed_jnp(q, kw, ke, vw, ve, causal=causal,
                                   window=window, q_offset=off, k_chunk=16)
    kd = ref.packed_kv_dequant_ref(kw, ke, d)
    vd = ref.packed_kv_dequant_ref(vw, ve, d)
    o2 = direct_attention(q, kd, vd, MaskInfo(q_offset=s - t, causal=causal,
                                              window=window))
    np.testing.assert_allclose(np.asarray(o), np.asarray(o2), atol=2e-6)


def test_dispatcher_routes_to_fallback_on_cpu():
    b, t, h, kv, d, s = 1, 4, 2, 2, 64, 16
    q = jax.random.normal(jax.random.PRNGKey(16), (b, t, h, d), jnp.float32)
    _, kw, ke = _planes(17, (b, s, kv, d), 8)
    _, vw, ve = _planes(18, (b, s, kv, d), 8)
    o = ops.flash_attention_packed(q, kw, ke, vw, ve, causal=True,
                                   q_offset=s - t)
    assert o.shape == q.shape and o.dtype == q.dtype
    route, reason = ops.last_fap_route()
    assert route == "fallback" and "non-tpu" in reason


# ---------------- GQA grid + scalar-prefetch q_offset (the tentpole) ------

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("ratio", [1, 2, 4])
def test_kernel_gqa_traced_offset_scan_parity(monkeypatch, bits, ratio):
    """The decode workload on the kernel path: GQA-shaped q (kv_heads in
    {h, h/2, h/4}) with a **traced** q_offset carried by a lax.scan —
    exactly what decode_step threads from cache["index"] — runs the Pallas
    kernel (scalar-prefetch offset + GQA grid, interpret mode on CPU)
    bit-identical to the tile-local jnp fallback."""
    b, t, kv, d, s = 2, 4, 2, 64, 32
    h = kv * ratio
    q = jax.random.normal(jax.random.PRNGKey(ratio), (b, t, h, d),
                          jnp.float32)
    _, kw, ke = _planes(30 + ratio + bits, (b, s, kv, d), bits)
    _, vw, ve = _planes(40 + ratio + bits, (b, s, kv, d), bits)

    def run(route):
        monkeypatch.setenv("REPRO_FAP_ROUTE", route)

        def body(off, _):
            o = ops.flash_attention_packed(q, kw, ke, vw, ve, causal=True,
                                           q_offset=off, bq=4, bk=16)
            return off + 1, o
        _, outs = jax.lax.scan(body, jnp.asarray(s - t, jnp.int32), None,
                               length=3)
        return outs

    ok = run("kernel")
    assert ops.last_fap_route()[0] == "kernel"
    oj = run("fallback")
    assert ops.last_fap_route()[0] == "fallback"
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(oj))


@pytest.mark.parametrize("window", [0, 8])
def test_kernel_gqa_window_tail_parity(monkeypatch, window):
    """GQA + sliding window + fp tail rows (the quantize-after-attend
    decode append) on the forced kernel route, bit-exact vs the fallback:
    the tail joins the last packed tile's update in both paths."""
    b, t, kv, g, d, s = 1, 1, 2, 2, 64, 32
    h = kv * g
    q = jax.random.normal(jax.random.PRNGKey(50), (b, t, h, d), jnp.float32)
    _, kw, ke = _planes(51, (b, s, kv, d), 4)
    _, vw, ve = _planes(52, (b, s, kv, d), 4)
    kt = jax.random.normal(jax.random.PRNGKey(53), (b, t, kv, d),
                           jnp.float32)
    vt = jax.random.normal(jax.random.PRNGKey(54), (b, t, kv, d),
                           jnp.float32)
    off = jnp.asarray(s - 1)

    def run(route):
        monkeypatch.setenv("REPRO_FAP_ROUTE", route)
        return jax.jit(lambda o: ops.flash_attention_packed(
            q, kw, ke, vw, ve, causal=True, window=window, q_offset=o,
            k_tail=kt, v_tail=vt, bq=1, bk=16))(off)

    np.testing.assert_array_equal(np.asarray(run("kernel")),
                                  np.asarray(run("fallback")))


def test_kernel_gqa_bit_exact_vs_expand_oracle():
    """The GQA grid (each packed plane row dequantized once per kv-head)
    is bit-identical to expanding every plane G-fold and running the MHA
    kernel — the memory expansion the grid exists to avoid changes no
    bit of the output."""
    b, t, kv, g, d, s = 2, 8, 2, 4, 64, 32
    h = kv * g
    q = jax.random.normal(jax.random.PRNGKey(60), (b, t, h, d), jnp.float32)
    _, kw, ke = _planes(61, (b, s, kv, d), 8)
    _, vw, ve = _planes(62, (b, s, kv, d), 8)
    qf = q.reshape(b, t, kv, g, d).transpose(0, 2, 3, 1, 4).reshape(
        b * kv, g, t, d)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * kv, s, -1)
    ok = flash_attention_packed_pallas(qf, fold(kw), fold(ke), fold(vw),
                                       fold(ve), causal=True, bq=4, bk=16)
    ok = ok.reshape(b, kv, g, t, d).transpose(0, 3, 1, 2, 4).reshape(
        b, t, h, d)
    oo = ref.flash_attention_packed_gqa_oracle(q, kw, ke, vw, ve,
                                               causal=True, bq=4, bk=16)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(oo))


# ---------------- dispatch routing (observable, forced, overridden) -------

def test_concrete_offset_normalization():
    """Every concrete 0-d scalar flavor lands on one int (one jit cache
    key, kernel-eligible); only true tracers return None."""
    assert ops.concrete_scalar_int(5) == 5
    assert ops.concrete_scalar_int(np.int64(5)) == 5
    assert ops.concrete_scalar_int(np.asarray(5)) == 5
    assert ops.concrete_scalar_int(jnp.asarray(5)) == 5          # weak-typed
    assert ops.concrete_scalar_int(jnp.asarray(5, jnp.int32)) == 5
    assert ops.concrete_scalar_int(jnp.arange(3)) is None        # not 0-d
    seen = []
    jax.jit(lambda x: seen.append(ops.concrete_scalar_int(x)))(jnp.asarray(5))
    assert seen == [None]                                        # tracer


def test_fap_dispatch_routing_table(monkeypatch):
    """Which route each (shape, offset, flag) combination takes, via
    last_fap_route — the observable half of the dispatch contract."""
    b, t, kv, d, s = 1, 4, 2, 64, 32
    q = jax.random.normal(jax.random.PRNGKey(70), (b, t, 4, d), jnp.float32)
    _, kw, ke = _planes(71, (b, s, kv, d), 8)
    _, vw, ve = _planes(72, (b, s, kv, d), 8)

    def route(env, q=q, planes=(kw, ke, vw, ve), **kwargs):
        monkeypatch.setenv("REPRO_FAP_ROUTE", env)
        ops.flash_attention_packed(q, *planes, causal=True, **kwargs)
        return ops.last_fap_route()

    # auto on CPU -> fallback (the jnp simulation default)
    r, why = route("auto")
    assert r == "fallback" and "non-tpu" in why
    # forced kernel serves GQA + concrete and traced offsets
    assert route("kernel")[0] == "kernel"
    assert route("kernel", q_offset=np.asarray(s - t))[0] == "kernel"
    r, _ = route("kernel", q_offset=jax.jit(lambda: jnp.asarray(7))())
    assert r == "kernel"
    # traced is_global overrides any forcing (per-layer global attention)
    r, why = route("kernel", is_global=jnp.asarray(True))
    assert r == "fallback" and "is_global" in why
    # non-grouping head counts can never take the GQA grid (decision
    # level: h % kv != 0 is not a servable attention shape on any route)
    monkeypatch.setenv("REPRO_FAP_ROUTE", "kernel")
    use, why = ops.fap_route_decision(t, s, 4, 3, has_is_global=False,
                                      bq=256, bk=512)
    assert not use and "not a multiple" in why
    # ragged tile lengths fall back regardless of forcing
    r, why = route("kernel", bk=24)
    assert r == "fallback" and "ragged" in why
    # explicit fallback wins even on kernel-eligible shapes
    assert route("fallback")[0] == "fallback"


# ---------------- packed decode: in-place append, never unpacked ----------

_PLANE_KEYS = ("k_words", "k_exp", "v_words", "v_exp")


def _setup(arch):
    cfg = reduced_config(arch)
    fz, tr = M.init_model(jax.random.PRNGKey(0), cfg, FP)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 4, cfg.vocab)
    return cfg, fz, tr, prompt


@pytest.mark.parametrize("bits", [4, 8])
def test_generate_inplace_token_identical_to_roundtrip(bits):
    """The restructured decode loop (in-place packed append + fused
    attention) produces **exactly** the same tokens as the legacy
    unpack-attend-repack round-trip at every bit-width: both paths
    quantize each token exactly once, and the quantize-after-attend
    append (fp tail) means the current token is attended at full
    precision on both sides — the documented b<8 A/B gap is closed."""
    cfg, fz, tr, prompt = _setup("granite_3_2b")
    out_ip = E.greedy_generate(fz, tr, prompt, cfg, FP, max_new=5,
                               kv_quant_bits=bits)
    out_rt = E.greedy_generate(fz, tr, prompt, cfg, FP, max_new=5,
                               kv_quant_bits=bits, kv_inplace=False)
    np.testing.assert_array_equal(np.asarray(out_ip), np.asarray(out_rt))


def test_generate_inplace_hybrid_sliding_window():
    """hymba: hybrid attention+SSM cache with sliding-window + global
    layers — the packed path must thread window/is_global masks and leave
    SSM state untouched. With the quantize-after-attend append the
    in-place path is token-identical (exact) to the round-trip reference,
    near-tie argmaxes included."""
    cfg, fz, tr, prompt = _setup("hymba_1_5b")
    out_ip = E.greedy_generate(fz, tr, prompt, cfg, FP, max_new=5,
                               kv_quant_bits=8)
    out_rt = E.greedy_generate(fz, tr, prompt, cfg, FP, max_new=5,
                               kv_quant_bits=8, kv_inplace=False)
    np.testing.assert_array_equal(np.asarray(out_ip), np.asarray(out_rt))


def test_generate_kernel_route_token_identical(monkeypatch):
    """Acceptance: greedy_generate(kv_quant_bits=4) with the kernel route
    forced (interpret mode on CPU) emits the same tokens as the jnp
    fallback route — the decode scan's traced cache["index"] reaches the
    scalar-prefetch kernel and the GQA grid serves granite's h=4*kv
    heads without expanding the packed planes."""
    cfg, fz, tr, prompt = _setup("granite_3_2b")
    monkeypatch.setenv("REPRO_FAP_ROUTE", "kernel")
    out_k = E.greedy_generate(fz, tr, prompt, cfg, FP, max_new=4,
                              kv_quant_bits=4)
    assert ops.last_fap_route()[0] == "kernel"
    monkeypatch.setenv("REPRO_FAP_ROUTE", "fallback")
    out_j = E.greedy_generate(fz, tr, prompt, cfg, FP, max_new=4,
                              kv_quant_bits=4)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_j))


def test_decode_never_materializes_unpacked_cache():
    """Peak live KV bytes ≈ packed bytes: the scan carry holds only the
    word/exponent planes (buffer inspection) and their measured nbytes
    match the analytic row-planar footprint exactly."""
    cfg, fz, tr, prompt = _setup("granite_3_2b")
    max_len = 16
    cache = E.init_decode_cache(cfg, 2, max_len)
    _, cache = E.prefill(fz, tr, {"tokens": prompt}, cache, cfg, FP)
    bf16_bytes = cache["k"].nbytes + cache["v"].nbytes
    pc = E.pack_decode_cache_planar(cache, bits=8)
    # buffer inspection: no unpacked k/v leaves anywhere in the carry
    assert "k" not in pc and "v" not in pc
    tok = jnp.zeros((2, 1), jnp.int32)
    _, pc = E.decode_step(fz, tr, tok, pc, cfg, FP)
    assert set(k for k in pc if k != "index") == set(_PLANE_KEYS)
    d = cfg.resolved_head_dim
    g = E._kv_pack_group(d, 32)
    bits, batch = 8, 2
    n_rows = cfg.n_layers * batch * max_len * cfg.n_kv_heads
    analytic = 2 * n_rows * (kv_row_words(d, bits) * 4 + d // g)  # k and v
    assert E.packed_cache_nbytes(pc) == analytic
    # decode_step must not grow the planes
    _, pc2 = E.decode_step(fz, tr, tok, pc, cfg, FP)
    assert E.packed_cache_nbytes(pc2) == analytic
    # at a realistic head_dim the planes beat bf16 by ~2x at b=8 (the
    # reduced configs' tiny head_dim pays padding; assert there instead
    # on the aligned shape below)
    k = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 64, 4, 128))
    w, e = quant_pack_kv_rows(k, 8)
    packed_bytes = w.nbytes + e.nbytes
    assert packed_bytes < 0.55 * k.astype(jnp.bfloat16).nbytes
    del bf16_bytes


def test_inplace_append_planes_repack_idempotent():
    """Mid-scan invariant of the planar layout: unpack -> re-pack of the
    in-place-appended planes reproduces the words and exponents exactly
    (GSE re-quantization of GSE-exact values is lossless), so appended
    positions never accumulate error across the decode scan."""
    cfg, fz, tr, prompt = _setup("granite_3_2b")
    cache = E.init_decode_cache(cfg, 2, 16)
    _, cache = E.prefill(fz, tr, {"tokens": prompt}, cache, cfg, FP)
    pc = E.pack_decode_cache_planar(cache, bits=6)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(2):
        _, pc = E.decode_step(fz, tr, tok, pc, cfg, FP)
    d = cfg.resolved_head_dim
    back = E.unpack_decode_cache_planar(pc, d, jnp.float32)
    repack = E.pack_decode_cache_planar(
        {"k": back["k"], "v": back["v"], "index": back["index"]}, bits=6)
    for key in _PLANE_KEYS:
        np.testing.assert_array_equal(np.asarray(pc[key]),
                                      np.asarray(repack[key]))


def test_whisper_packed_cross_attention_decode():
    """encdec: self **and** cross caches packed; decode logits agree with
    the unpacked cache path within quantization tolerance."""
    cfg = reduced_config("whisper_small")
    fz, tr = M.init_model(jax.random.PRNGKey(0), cfg, FP)
    key = jax.random.PRNGKey(1)
    prompt = jax.random.randint(key, (2, 8), 4, cfg.vocab)
    frames = jax.random.normal(key, (2, cfg.encoder_len, cfg.d_model))
    cache = E.init_decode_cache(cfg, 2, 16, enc_len=cfg.encoder_len)
    logits, cache = E.prefill(fz, tr, dict(tokens=prompt, frames=frames),
                              cache, cfg, FP)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    l_u, _ = E.decode_step(fz, tr, tok, dict(cache), cfg, FP)
    pc = E.pack_decode_cache_planar(cache, bits=8)
    assert {"ck_words", "ck_exp", "cv_words", "cv_exp"} <= set(pc)
    l_p, pc2 = E.decode_step(fz, tr, tok, pc, cfg, FP)
    rel = float(jnp.max(jnp.abs(l_p - l_u)) / jnp.max(jnp.abs(l_u)))
    assert rel < 0.05, rel
    assert "ck" not in pc2 and "k" not in pc2
