"""Mamba-2 SSD: chunked-scan vs naive recurrence; decode-state updates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# Real hypothesis when installed; deterministic reduced sweep otherwise
# (keeps collection green in bare environments -- see _hypothesis_compat).
from _hypothesis_compat import given, settings, st

from repro.core.policy import QuantPolicy
from repro.models.config import ModelConfig
from repro.models.ssm import ssd_chunked, ssm_apply, ssm_init, ssm_cache_init

FP = QuantPolicy(fmt="none", a_bits=None, w_bits=None, g_bits=None,
                 adapter_bits=None, base_w_nf4=False, rank=0)


def _naive(xh, dt, A, Bm, Cm, D, init_state=None):
    b, t, h, p = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)
    state = (jnp.zeros((b, h, n, p)) if init_state is None else init_state)
    ys = []
    for i in range(t):
        a = jnp.exp(dt[:, i] * A[None])
        upd = (Bh[:, i] * dt[:, i][..., None])[..., :, None] \
            * xh[:, i][:, :, None, :]
        state = state * a[..., None, None] + upd
        ys.append(jnp.einsum("bhn,bhnp->bhp", Ch[:, i], state)
                  + xh[:, i] * D[None, :, None])
    return jnp.stack(ys, 1), state


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 500), chunk=st.sampled_from([4, 8, 16]))
def test_ssd_chunked_equals_recurrence(seed, chunk):
    cfg = ModelConfig(ssm_chunk=chunk, ssm_state=8, ssm_head_dim=8)
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    b, t, h, p, g, n = 2, 32, 4, 8, 2, 8
    xh = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, t, g, n))
    Cm = jax.random.normal(ks[4], (b, t, g, n))
    D = jnp.ones((h,))
    y, fs = ssd_chunked(xh, dt, A, Bm, Cm, D, cfg, FP)
    yr, fsr = _naive(xh, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fsr), atol=2e-4)


def test_ssd_init_state_carry():
    """Prefill state seeding: running 2x16 tokens with carried state equals
    one 32-token pass."""
    cfg = ModelConfig(ssm_chunk=8, ssm_state=8, ssm_head_dim=8)
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    b, t, h, p, g, n = 1, 32, 2, 8, 1, 8
    xh = jax.random.normal(ks[0], (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    Bm = jax.random.normal(ks[3], (b, t, g, n))
    Cm = jax.random.normal(ks[4], (b, t, g, n))
    D = jnp.zeros((h,))
    y_full, fs_full = ssd_chunked(xh, dt, A, Bm, Cm, D, cfg, FP)
    y1, s1 = ssd_chunked(xh[:, :16], dt[:, :16], A, Bm[:, :16], Cm[:, :16],
                         D, cfg, FP)
    y2, s2 = ssd_chunked(xh[:, 16:], dt[:, 16:], A, Bm[:, 16:], Cm[:, 16:],
                         D, cfg, FP, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(fs_full),
                               atol=2e-4)


def test_ssm_module_decode_matches_full():
    cfg = ModelConfig(family="ssm", d_model=32, ssm_state=8, ssm_head_dim=8,
                      ssm_chunk=8, norm_eps=1e-6)
    fz, tr = ssm_init(jax.random.PRNGKey(0), cfg, FP)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 17, 32),
                          jnp.float32).astype(jnp.bfloat16)
    y_full, _ = ssm_apply(fz, tr, x, cfg, FP)
    # prefill 16 then decode 1
    cache = {k: v[0] for k, v in ssm_cache_init(cfg, 2, 1).items()}
    y_pre, cache = ssm_apply(fz, tr, x[:, :16], cfg, FP, cache=cache)
    y_dec, _ = ssm_apply(fz, tr, x[:, 16:17], cfg, FP, cache=cache)
    err = float(jnp.max(jnp.abs(
        y_dec.astype(jnp.float32) - y_full[:, 16:17].astype(jnp.float32))))
    assert err < 0.05, err     # bf16 path tolerance
