"""Rule fixtures for the GSE parity-contract linter (repro.analysis.lint).

Each rule gets the four-quadrant treatment: a positive (the violation is
caught), a negative (the blessed/equivalent-but-legal form passes), a
pragma-disabled case, and a baseline-suppressed case. Plus the acceptance
check: the real ``src/`` tree lints clean against the checked-in baseline.
"""
import json
from pathlib import Path

import pytest

from repro.analysis import lint

SRC_ROOT = Path(__file__).resolve().parents[1] / "src"
BASELINE = Path(__file__).resolve().parents[1] / "tools" / \
    "gse_lint_baseline.json"


def _tree(tmp_path, files):
    """Materialize {relpath: source} under tmp_path, return the root."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src, encoding="utf-8")
    return tmp_path


def _run(root, **files):
    _tree(root, files)
    return lint.lint_paths([root], root)


def _rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------- R1 ------

def test_r1_flags_exp2_log2_and_dynamic_pow(tmp_path):
    fs = _run(tmp_path, **{"repro/core/x.py": (
        "import jax.numpy as jnp\n"
        "def f(e):\n"
        "    return jnp.exp2(e), jnp.log2(e), 2.0 ** e\n")})
    assert [f.rule for f in fs] == ["R1", "R1", "R1"]


def test_r1_allows_blessed_files_and_const_pow(tmp_path):
    body = ("import jax.numpy as jnp\n"
            "def f(e):\n"
            "    return jnp.exp2(e)\n")
    fs = _run(tmp_path, **{
        "repro/core/gse.py": body,              # blessed: helper home
        "repro/kernels/ref.py": body,           # blessed: numpy oracles
        "repro/core/ok.py": (
            "LIM = 2 ** 31 - 1\n"
            "def f(bits):\n"
            "    return 2 ** (8 - 1), bits\n"),  # const-folded host math
    })
    assert fs == []


def test_r1_pragma_disable(tmp_path):
    fs = _run(tmp_path, **{"repro/core/x.py": (
        "import jax.numpy as jnp\n"
        "def f(e):\n"
        "    return jnp.exp2(e)  # gse-lint: disable=R1\n")})
    assert fs == []


# ---------------------------------------------------------------- R2 ------

def test_r2_flags_raw_repro_env_reads(tmp_path):
    fs = _run(tmp_path, **{"repro/core/x.py": (
        "import os\n"
        "a = os.environ.get('REPRO_FOO')\n"
        "b = os.getenv('REPRO_BAR', '0')\n"
        "c = os.environ['REPRO_BAZ']\n")})
    assert [f.rule for f in fs] == ["R2", "R2", "R2"]


def test_r2_allows_registry_writes_and_non_repro_keys(tmp_path):
    fs = _run(tmp_path, **{
        # the registry module is the one blessed reader
        "repro/kernels/ops.py": (
            "import os\n"
            "v = os.environ.get('REPRO_FOO', 'auto')\n"),
        "repro/core/x.py": (
            "import os\n"
            "os.environ['REPRO_FOO'] = '1'\n"       # writes are fine
            "os.environ.pop('REPRO_FOO', None)\n"
            "p = os.environ.get('XLA_FLAGS', '')\n"),  # non-REPRO key
    })
    assert fs == []


def test_r2_file_pragma(tmp_path):
    fs = _run(tmp_path, **{"repro/core/x.py": (
        "# gse-lint: disable-file=R2\n"
        "import os\n"
        "a = os.environ.get('REPRO_FOO')\n"
        "b = os.environ.get('REPRO_BAR')\n")})
    assert fs == []


# ---------------------------------------------------------------- R3 ------

_REF = "def covered_ref(x):\n    return x\n"
_KERN = ("import jax.experimental.pallas as pl\n"
         "def covered_pallas(x):\n"
         "    return pl.pallas_call(lambda r: r)(x)\n"
         "def orphan_pallas(x):\n"
         "    return pl.pallas_call(lambda r: r)(x)\n")


def test_r3_requires_oracle_per_kernel_entry(tmp_path):
    fs = _run(tmp_path, **{"repro/kernels/ref.py": _REF,
                           "repro/kernels/k.py": _KERN})
    assert [f.rule for f in fs] == ["R3"]
    assert "orphan_pallas" in fs[0].message


def test_r3_oracle_suffix_variants_and_scope(tmp_path):
    fs = _run(tmp_path, **{
        "repro/kernels/ref.py": ("def a_oracle(x):\n    return x\n"),
        "repro/kernels/k.py": (
            "import jax.experimental.pallas as pl\n"
            "def a_pallas(x):\n"                     # matches a_oracle
            "    return pl.pallas_call(lambda r: r)(x)\n"),
        # pallas_call outside kernels/ is out of R3's jurisdiction
        "repro/core/x.py": (
            "import jax.experimental.pallas as pl\n"
            "def rogue(x):\n"
            "    return pl.pallas_call(lambda r: r)(x)\n"),
    })
    assert fs == []


# ---------------------------------------------------------------- R4 ------

def test_r4_flags_word_shifts_and_plane_astype(tmp_path):
    fs = _run(tmp_path, **{"repro/core/x.py": (
        "def f(words, t):\n"
        "    lo = words >> 5\n"
        "    hi = words << 2\n"
        "    m = t.mantissa_words.astype('float32')\n"
        "    return lo, hi, m\n")})
    assert [f.rule for f in fs] == ["R4", "R4", "R4"]


def test_r4_blessed_unpack_bodies_and_nonword_shifts(tmp_path):
    fs = _run(tmp_path, **{
        "repro/core/gse.py": (
            "def unpack(words):\n"
            "    return words >> 5\n"),              # the shared body
        "repro/core/x.py": (
            "def f(qmax, bits):\n"
            "    return qmax << bits\n"),            # not word data
    })
    assert fs == []


def test_r5_flags_hand_plane_prefix_slice(tmp_path):
    """A width-bounded slice of packed words outside the blessed bodies
    is a hand-rolled plane-prefix view — with_bits/plane_prefix_words is
    the one sanctioned slice."""
    fs = _run(tmp_path, **{"repro/serve/x.py": (
        "def f(words, bits, chunks, kw):\n"
        "    a = words[..., : bits * chunks]\n"
        "    b = kw.mantissa_words[:, : n_planes(bits)]\n"
        "    return a, b\n")})
    assert [f.rule for f in fs] == ["R5", "R5"]


def test_r5_blessed_bodies_and_nonwidth_slices(tmp_path):
    body = ("def view(words, bits, chunks):\n"
            "    return words[..., : bits * chunks]\n")
    fs = _run(tmp_path, **{
        "repro/core/gse.py": body,          # blessed: the sanctioned slice
        "repro/kernels/ref.py": body,       # blessed: the numpy oracles
        "repro/serve/ok.py": (
            "def f(words, n, x, bits):\n"
            "    a = words[..., :n]\n"       # bound is not a width
            "    b = words[:n]\n"
            "    c = x[..., : bits * 4]\n"   # target is not word data
            "    return a, b, c\n"),
    })
    assert fs == []


def test_r5_pragma_disable(tmp_path):
    fs = _run(tmp_path, **{"repro/serve/x.py": (
        "def f(words, bits, chunks):\n"
        "    return words[..., : bits * chunks]"
        "  # gse-lint: disable=R5\n")})
    assert fs == []


# ----------------------------------------------------------- baseline -----

def test_baseline_suppression_roundtrip(tmp_path):
    files = {"repro/core/x.py": (
        "import jax.numpy as jnp\n"
        "def f(e):\n"
        "    return jnp.exp2(e)\n")}
    root = _tree(tmp_path / "t", files)
    findings = lint.lint_paths([root], root)
    assert _rules(findings) == ["R1"]

    bl = tmp_path / "baseline.json"
    lint.write_baseline(bl, findings)
    fresh, grandfathered = lint.split_baselined(
        findings, lint.load_baseline(bl))
    assert fresh == [] and len(grandfathered) == 1

    # the fingerprint is line-number free: shifting the def down two
    # lines must not resurface the finding...
    root2 = _tree(tmp_path / "t2", {"repro/core/x.py": (
        "import jax.numpy as jnp\n\n\n"
        "def f(e):\n"
        "    return jnp.exp2(e)\n")})
    fresh2, _ = lint.split_baselined(
        lint.lint_paths([root2], root2), lint.load_baseline(bl))
    assert fresh2 == []
    # ...but a *new* violation in the same file is still fresh
    root3 = _tree(tmp_path / "t3", {"repro/core/x.py": (
        "import jax.numpy as jnp\n"
        "def f(e):\n"
        "    return jnp.exp2(e)\n"
        "def g(e):\n"
        "    return jnp.log2(e)\n")})
    fresh3, _ = lint.split_baselined(
        lint.lint_paths([root3], root3), lint.load_baseline(bl))
    assert len(fresh3) == 1 and "log2" in fresh3[0].code


def test_cli_json_report_and_exit_codes(tmp_path, capsys):
    root = _tree(tmp_path, {"repro/core/x.py": (
        "import jax.numpy as jnp\n"
        "def f(e):\n"
        "    return jnp.exp2(e)\n")})
    out = tmp_path / "report.json"
    bl = tmp_path / "baseline.json"
    rc = lint.main([str(root), "--root", str(root), "--baseline", str(bl),
                    "--json", str(out)])
    assert rc == 1
    report = json.loads(out.read_text())
    assert report["schema"] == lint.REPORT_SCHEMA
    assert not report["ok"] and len(report["fresh"]) == 1
    # grandfather it, then the same tree exits clean
    assert lint.main([str(root), "--root", str(root), "--baseline",
                      str(bl), "--update-baseline"]) == 0
    assert lint.main([str(root), "--root", str(root), "--baseline",
                      str(bl), "--json", str(out)]) == 0
    assert json.loads(out.read_text())["ok"]


# ------------------------------------------------- the real tree ----------

def test_src_tree_clean_against_checked_in_baseline():
    """Acceptance: zero non-baseline violations on src/ (the two satellite
    fixes — compression.py exact exponent math, the NF4 knob through the
    tristate registry — were this gate's first real catches)."""
    findings = lint.lint_paths([SRC_ROOT], SRC_ROOT)
    fresh, _ = lint.split_baselined(findings,
                                    lint.load_baseline(BASELINE))
    assert fresh == [], "\n".join(f.render() for f in fresh)
