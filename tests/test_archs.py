"""Per-assigned-architecture smoke tests: REDUCED config of the same family,
one forward + one train step on CPU, asserting output shapes and no NaNs
(assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config, reduced_config
from repro.core.policy import QuantPolicy
from repro.models import model as M
from repro.optim.adamw8bit import AdamW8bit
from repro.train.step import TrainConfig, make_train_step

ARCHS = all_arch_names() + ["llama2_7b"]
POLICY = QuantPolicy.gsq(6, rank=8)


def _batch(cfg, b=2, t=32):
    key = jax.random.PRNGKey(7)
    batch = {
        "labels": jax.random.randint(key, (b, t), 0, cfg.vocab),
        "loss_mask": jnp.ones((b, t), jnp.float32),
    }
    if cfg.frontend == "vlm":
        batch["inputs_embeds"] = jax.random.normal(
            key, (b, t, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(key, (b, t), 0, cfg.vocab)
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_fields(arch):
    cfg = get_config(arch)
    assert cfg.padded_vocab % cfg.vocab_pad_multiple == 0
    assert cfg.padded_vocab >= cfg.vocab
    if cfg.uses_attention:
        assert cfg.n_heads % cfg.n_kv_heads == 0
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count()


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_forward(arch):
    cfg = reduced_config(arch)
    fz, tr = M.init_model(jax.random.PRNGKey(0), cfg, POLICY)
    batch = _batch(cfg)
    logits = M.forward(fz, tr, batch, cfg, POLICY)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_train_step(arch):
    cfg = reduced_config(arch)
    fz, tr = M.init_model(jax.random.PRNGKey(1), cfg, POLICY)
    opt = AdamW8bit(lr=1e-3)
    step = make_train_step(cfg, POLICY, opt, TrainConfig(accum_steps=1))
    opt_state = opt.init(tr)
    res = jax.tree.map(lambda p: jnp.zeros((0,), jnp.float32), tr)
    tr2, opt_state2, _, metrics = step(fz, tr, opt_state, res, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    # adapters actually moved (B gets grads from step 1)
    moved = jax.tree.reduce(
        lambda acc, ab: acc + float(jnp.sum(jnp.abs(ab[0] - ab[1]))),
        jax.tree.map(lambda a, b: (a, b), tr, tr2), 0.0)
    assert moved > 0.0


def test_arctic_dense_residual_present():
    cfg = reduced_config("arctic_480b")
    fz, tr = M.init_model(jax.random.PRNGKey(2), cfg, POLICY)
    layer_fz = jax.tree.map(lambda x: x, fz["layers"])
    assert "moe" in layer_fz and "mlp" in layer_fz


def test_param_count_sanity_full_configs():
    """Rough magnitude check of the 6·N·D bookkeeping per arch."""
    expect = {
        "llama2_7b": (6e9, 8e9),
        "gemma_7b": (7e9, 10.5e9),     # incl. 256k-vocab embeddings
        "qwen3_14b": (13e9, 17e9),
        "mamba2_2_7b": (2.2e9, 3.2e9),
        "arctic_480b": (4.3e11, 5.3e11),
        "granite_3_2b": (2.2e9, 3.2e9),
        "qwen2_1_5b": (1.2e9, 2.0e9),
        "hymba_1_5b": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)
