"""Quickstart: GSQ-Tuning in ~40 lines.

Builds a small GSQ-LoRA transformer (NF4 frozen base + GSE-quantized
forward/backward), fine-tunes it on the synthetic instruction tasks for a
few dozen steps, and prints the loss curve.

    PYTHONPATH=src python examples/quickstart.py [--steps N]

``--steps`` shrinks the run (CI smokes it at a handful of steps).
"""
import argparse

import jax

from repro.core.policy import QuantPolicy
from repro.data.pipeline import DataConfig
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.adamw8bit import AdamW8bit
from repro.train.runner import RunnerConfig, TrainingRunner
from repro.train.step import TrainConfig


def main(total_steps: int = 60):
    # the paper's W4-A6-G6 configuration at LoRA rank 16
    policy = QuantPolicy.gsq(bits=6, rank=16)
    cfg = ModelConfig(name="quickstart", family="dense", n_layers=2,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab=128, vocab_pad_multiple=64)
    frozen, train = M.init_model(jax.random.PRNGKey(0), cfg, policy)

    runner = TrainingRunner(
        cfg, policy,
        DataConfig(vocab=128, seq_len=64, global_batch=16,
                   task_mix=("copy", "reverse")),
        AdamW8bit(lr=5e-3, warmup_steps=10),
        TrainConfig(accum_steps=1),
        RunnerConfig(total_steps=total_steps,
                     checkpoint_every=min(50, total_steps),
                     checkpoint_dir="/tmp/gsq_quickstart", log_every=10),
        frozen=frozen, train=train)
    runner.install_signal_handlers()
    hist = runner.run()
    print(f"\npolicy: {policy.label()}")
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"over {len(hist)} steps")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60,
                    help="training steps (CI smoke uses a small count)")
    main(ap.parse_args().steps)
