"""Serving example: batched greedy generation from a GSQ-quantized model
(prefill + KV-cached decode), demonstrating the decode path the decode_32k
dry-run cells lower.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.core.policy import QuantPolicy
from repro.models import model as M
from repro.serve import engine as E


def main():
    policy = QuantPolicy.gsq(6, rank=8)
    cfg = reduced_config("granite_3_2b")
    frozen, train = M.init_model(jax.random.PRNGKey(0), cfg, policy)

    batch = 4
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, 12), 4,
                                cfg.vocab)
    t0 = time.perf_counter()
    out = E.greedy_generate(frozen, train, prompt, cfg, policy, max_new=16)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"arch: {cfg.name} (reduced) under {policy.label()}")
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({batch * 16 / dt:.1f} tok/s incl. compile)")
    for row in out[:2]:
        print("  ", list(map(int, row)))


if __name__ == "__main__":
    main()
