"""Serving example: batched greedy generation from a GSQ-quantized model
(prefill + KV-cached decode), demonstrating the decode path the decode_32k
dry-run cells lower.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.core.policy import QuantPolicy
from repro.models import model as M
from repro.serve import engine as E


def main():
    policy = QuantPolicy.gsq(6, rank=8)
    cfg = reduced_config("granite_3_2b")
    frozen, train = M.init_model(jax.random.PRNGKey(0), cfg, policy)

    batch = 4
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, 12), 4,
                                cfg.vocab)
    t0 = time.perf_counter()
    out = E.greedy_generate(frozen, train, prompt, cfg, policy, max_new=16)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"arch: {cfg.name} (reduced) under {policy.label()}")
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({batch * 16 / dt:.1f} tok/s incl. compile)")
    for row in out[:2]:
        print("  ", list(map(int, row)))

    # decode again with the KV cache held bit-packed THROUGH attention:
    # after prefill the cache converts once to row-planar packed planes,
    # each step appends the new token's quantized rows in place and
    # attends fused with tile-local dequant — the full unpacked cache is
    # never materialized (docs/architecture.md, serve path)
    out_p = E.greedy_generate(frozen, train, prompt, cfg, policy,
                              max_new=16, kv_quant_bits=8)
    # the legacy per-step unpack->attend->re-pack round-trip, for A/B
    out_rt = E.greedy_generate(frozen, train, prompt, cfg, policy,
                               max_new=16, kv_quant_bits=8,
                               kv_inplace=False)
    cache = E.init_decode_cache(cfg, batch, 12 + 16)
    _, cache = E.prefill(frozen, train, {"tokens": prompt}, cache, cfg,
                         policy)
    planar = E.pack_decode_cache_planar(cache, bits=8)
    flat = E.pack_decode_cache(cache, bits=8)
    raw = cache["k"].nbytes + cache["v"].nbytes
    agree = float(jnp.mean((out_p == out).astype(jnp.float32)))
    agree_rt = float(jnp.mean((out_p == out_rt).astype(jnp.float32)))
    print(f"packed-KV greedy tokens matching bf16-KV: {agree:.0%} "
          f"(8-bit KV noise can flip near-tie argmaxes)")
    assert agree_rt == 1.0, agree_rt
    print(f"in-place packed decode matching round-trip: {agree_rt:.0%} "
          f"(token-identical by construction — quantize-after-attend)")
    print(f"kv cache bytes: bf16={raw} "
          f"flat8={E.packed_cache_nbytes(flat)} "
          f"({E.packed_cache_nbytes(flat) / raw:.1%}, at-rest snapshot) "
          f"planar8={E.packed_cache_nbytes(planar)} (decode-resident; "
          f"this toy head_dim={cfg.resolved_head_dim} pays full 32-chunk "
          f"padding — real head dims are 32-aligned and land at "
          f"~(b+8/g)/16, see docs/gse-format.md §4)")
    print("peak live KV during decode: packed planes + one attention "
          "tile (memory_model.py realized_packed_kv rows)")


if __name__ == "__main__":
    main()
