"""Serving example: batched greedy generation from a GSQ-quantized model
(prefill + KV-cached decode), demonstrating the decode path the decode_32k
dry-run cells lower.

    PYTHONPATH=src python examples/serve_quantized.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.core.policy import QuantPolicy
from repro.models import model as M
from repro.serve import engine as E


def main():
    policy = QuantPolicy.gsq(6, rank=8)
    cfg = reduced_config("granite_3_2b")
    frozen, train = M.init_model(jax.random.PRNGKey(0), cfg, policy)

    batch = 4
    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, 12), 4,
                                cfg.vocab)
    t0 = time.perf_counter()
    out = E.greedy_generate(frozen, train, prompt, cfg, policy, max_new=16)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"arch: {cfg.name} (reduced) under {policy.label()}")
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({batch * 16 / dt:.1f} tok/s incl. compile)")
    for row in out[:2]:
        print("  ", list(map(int, row)))

    # decode again with the KV cache held bit-packed between steps: live
    # cache bytes drop to ~(b + 5/G)/16 of bf16 (observable, not analytic)
    out_p = E.greedy_generate(frozen, train, prompt, cfg, policy,
                              max_new=16, kv_quant_bits=8)
    cache = E.init_decode_cache(cfg, batch, 12 + 16)
    _, cache = E.prefill(frozen, train, {"tokens": prompt}, cache, cfg,
                         policy)
    packed = E.pack_decode_cache(cache, bits=8)
    raw = cache["k"].nbytes + cache["v"].nbytes
    agree = float(jnp.mean((out_p == out).astype(jnp.float32)))
    print(f"packed-KV greedy tokens matching bf16-KV: {agree:.0%} "
          f"(8-bit KV noise can flip near-tie argmaxes)")
    print(f"kv cache bytes: bf16={raw} packed8={E.packed_cache_nbytes(packed)} "
          f"({E.packed_cache_nbytes(packed) / raw:.1%})")


if __name__ == "__main__":
    main()
