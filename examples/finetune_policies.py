"""End-to-end driver: fine-tune the same model under the paper's policy
ladder (QLoRA-BF16 vs GSQ 8/6/5-bit) for a few hundred steps and compare —
the proxy-scale version of paper Tab. 1.

    PYTHONPATH=src python examples/finetune_policies.py [--steps 200]

``--residual-sweep`` instead varies only the packed backward-residual
width: GSQ 8-bit compute with ``residual_bits`` b∈{8,6,4} — the forward
GEMMs are identical, the saved-for-backward Q(X)/Q(W) streams are stored
at b bits (a re-quantization at pack time; the read side of the same knob
is the plane-prefix view, docs/gse-format.md §7). Prints the loss
trajectory per width — the table recorded in docs/benchmarks.md.
"""
import argparse

from benchmarks.common import run_proxy_finetune
from repro.core.policy import QuantPolicy


def residual_sweep(steps: int):
    import dataclasses
    base = QuantPolicy.gsq(8, rank=16, residuals_packed=True)
    runs = []
    for b in (8, 6, 4):
        pol = dataclasses.replace(base, residual_bits=b)
        m = run_proxy_finetune(pol, steps=steps,
                               record_every=max(steps // 4, 1))
        runs.append((b, m))
    marks = [s for s, _ in runs[0][1]["loss_trajectory"]]
    head = " ".join(f"{f'loss@{s}':>9s}" for s in marks)
    print(f"{'residual_bits':13s} {head} {'eval_loss':>9s} {'eval_acc':>8s}")
    for b, m in runs:
        traj = " ".join(f"{v:9.4f}" for _, v in m["loss_trajectory"])
        print(f"{b:<13d} {traj} {m['eval_loss']:9.4f} "
              f"{m['eval_acc']:8.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--residual-sweep", action="store_true",
                    help="sweep packed-residual width b in {8,6,4} at fixed "
                         "8-bit compute (loss-trajectory table)")
    args = ap.parse_args()
    if args.residual_sweep:
        residual_sweep(args.steps)
        return
    ladder = [
        ("QLoRA  4-16-16 (bf16 adapters)", QuantPolicy.qlora_bf16(rank=16)),
        ("GSQ    4-8-8   (GSE-INT8)", QuantPolicy.gsq(8, rank=16)),
        # packed backward residuals: same math (loss bit-identical to the
        # row above at matching bits), residuals stored at b + 5/group
        # bits/value instead of bf16
        ("GSQ    4-8-8   (packed residuals)",
         QuantPolicy.gsq(8, rank=16, residuals_packed=True)),
        ("GSQ    4-6-6   (GSE-INT6)", QuantPolicy.gsq(6, rank=16)),
        ("GSQ    4-5-5   (GSE-INT5)", QuantPolicy.gsq(5, rank=16)),
    ]
    print(f"{'policy':36s} {'eval_loss':>9s} {'eval_acc':>8s} "
          f"{'ms/step':>8s}")
    for name, pol in ladder:
        m = run_proxy_finetune(pol, steps=args.steps)
        print(f"{name:36s} {m['eval_loss']:9.4f} {m['eval_acc']:8.3f} "
              f"{m['us_per_step'] / 1000:8.1f}")


if __name__ == "__main__":
    main()
