"""End-to-end driver: fine-tune the same model under the paper's policy
ladder (QLoRA-BF16 vs GSQ 8/6/5-bit) for a few hundred steps and compare —
the proxy-scale version of paper Tab. 1.

    PYTHONPATH=src python examples/finetune_policies.py [--steps 200]
"""
import argparse

from benchmarks.common import run_proxy_finetune
from repro.core.policy import QuantPolicy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    ladder = [
        ("QLoRA  4-16-16 (bf16 adapters)", QuantPolicy.qlora_bf16(rank=16)),
        ("GSQ    4-8-8   (GSE-INT8)", QuantPolicy.gsq(8, rank=16)),
        # packed backward residuals: same math (loss bit-identical to the
        # row above at matching bits), residuals stored at b + 5/group
        # bits/value instead of bf16
        ("GSQ    4-8-8   (packed residuals)",
         QuantPolicy.gsq(8, rank=16, residuals_packed=True)),
        ("GSQ    4-6-6   (GSE-INT6)", QuantPolicy.gsq(6, rank=16)),
        ("GSQ    4-5-5   (GSE-INT5)", QuantPolicy.gsq(5, rank=16)),
    ]
    print(f"{'policy':36s} {'eval_loss':>9s} {'eval_acc':>8s} "
          f"{'ms/step':>8s}")
    for name, pol in ladder:
        m = run_proxy_finetune(pol, steps=args.steps)
        print(f"{name:36s} {m['eval_loss']:9.4f} {m['eval_acc']:8.3f} "
              f"{m['us_per_step'] / 1000:8.1f}")


if __name__ == "__main__":
    main()
